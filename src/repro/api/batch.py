"""The sweep execution engine behind ``solve_many`` (DESIGN.md §9).

Dispatch (batch mode "auto"):

  batched   specs on the built-in local backend whose algorithm provides a
            ``make_batch_round`` hook are grouped by their trace-shaping key
            (shape x algorithm x option x alpha x rounds x accounting x ...)
            and each group runs as ONE compiled program: ``lax.scan`` over
            rounds of ``lax.map`` over the stacked spec axis, compressor
            variation via ``lax.switch`` into the group's compressor table
            (``repro.core.fednl_batch``).  Per-spec trajectories are
            BIT-identical to sequential ``solve()`` calls.  With multiple
            local devices the spec axis is sharded across a 1-D mesh
            (``repro.launch.mesh.make_sweep_mesh``) via ``shard_map``.
  pool      wire-backend specs (star-loopback / star-tcp) are dispatched
            concurrently through a bounded thread pool — the event loops are
            I/O-bound, and every run owns its transport, so runs interleave
            without sharing state.
  warm      local-backend fallback specs identical except ``rounds`` share
            one trajectory prefix: a single warm-started session
            (``repro.api.session``) steps to each round count in ascending
            order and reports there — bit-identical to per-spec solves (the
            DESIGN.md §10 step-composability contract) with the shared
            prefix computed once.
  fallback  everything else (sharded, PP on local, tol early-stop, custom
            algorithms without a batch hook, ...) runs per spec through
            ``solve()`` — logged with the reason, never silently dropped.

Mode "vmap" swaps ``lax.map`` for ``jax.vmap`` over the spec axis in the
batched groups: maximal throughput on wide accelerators, but the batched
kernels (dot_general / Cholesky) may differ from the sequential ones by a
few ulps — the bit-identity guarantee is explicitly waived and logged.
Mode "never" runs everything sequentially in expansion order (what the
benchmark tables use, so per-spec wall time stays meaningful).
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import Algorithm, get_algorithm, get_backend
from repro.api.report import RoundRecord, RunReport, SweepReport

# event-loop backends that profit from concurrent dispatch; TCP spawns one
# OS process per client, so its width stays small
_POOL_WIDTH = {"star-loopback": 4, "star-tcp": 2}


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Plan:
    kind: str  # "batch" | "pool" | "warm" | "seq"
    indices: list[int]
    reason: str = ""


def _warm_key(spec):
    """Specs identical except ``rounds`` share one trajectory prefix: a
    single session solves the longest and reports every intermediate spec
    bit-identically (step composability, DESIGN.md §10).  None = ineligible."""
    from repro.api.backends import LOCAL_BACKEND

    if get_backend(spec.backend) is not LOCAL_BACKEND:
        return None  # session reuse is a local-simulation optimization
    if spec.tol > 0.0:
        return None  # early stop can end runs before the shared prefix
    return spec.replace(rounds=0)


def _batch_blockers(spec, algo: Algorithm, backend) -> list[str]:
    """Why this spec cannot join a vectorized batch (empty = it can)."""
    from repro.api.backends import LOCAL_BACKEND

    reasons = []
    if backend is not LOCAL_BACKEND:
        reasons.append(f"backend {spec.backend!r} is not the builtin local "
                       "simulation")
    if algo.make_batch_round is None:
        reasons.append(f"algorithm {spec.algorithm!r} has no batch-round hook")
    if algo.kind != "full":
        reasons.append("partial participation batches per spec only")
    if spec.tol > 0.0:
        reasons.append("tol early-stop needs a per-round host sync")
    if spec.rounds == 0:
        reasons.append("zero-round run")
    if spec.hessian_impl == "pallas":
        reasons.append("Pallas-wrapper Hessian routing is untested under the "
                       "batched scan")
    return reasons


def _group_key(spec, alpha: float, vectorize: str, dims: tuple) -> tuple:
    """Everything that shapes the batched trace EXCEPT compressor choice and
    seed — specs sharing this key run in one program.

    In the bit-exact "scan" layout the problem data itself is part of the
    key: the sequential path embeds ``z`` as a jit *constant*, and feeding it
    as a sliced ``lax.map`` operand instead changes the matmul kernels by an
    ulp (measured — DESIGN.md §9), so each distinct DataSpec gets its own
    compiled program with ``z`` closed over.  The "vmap" layout waives
    bit-identity and batches across data too.
    """
    return (
        spec.algorithm,
        spec.data if vectorize == "scan" else dims,
        spec.rounds,
        spec.objective,
        spec.lam,
        spec.option,
        spec.mu,
        spec.hess0,
        spec.hessian_impl,  # "fused" vs "jnp" shape different traces for d > 128
        spec.accounting,
        spec.ls_c,
        spec.ls_gamma,
        spec.ls_max_steps,
        spec.ls_tol,
        alpha,
    )


def resolved_alpha(spec, d: int) -> float:
    """The Hessian learning rate the round will actually use (compressor
    default unless the spec overrides it) — part of the group key so it can
    stay a compile-time constant inside the batched kernel."""
    if spec.compressor.alpha is not None:
        return float(spec.compressor.alpha)
    from repro.compressors import get_compressor
    from repro.linalg import triu_size

    cfg = spec.fednl_config()
    return float(get_compressor(spec.compressor.name, triu_size(d), cfg.k_for(d)).alpha)


def plan_sweep(specs: Sequence, batch_mode: str) -> tuple[list[_Plan], list[str]]:
    """Partition the expanded specs into batch groups, pool groups and
    per-spec fallbacks.  Validation (registry lookups, capability checks)
    happens here for EVERY spec before anything runs, so a bad spec fails
    the whole call upfront with the same error ``solve()`` raises."""
    from repro.api.facade import check_spec

    log: list[str] = []
    batch_groups: dict[tuple, list[int]] = {}
    pool_groups: dict[str, list[int]] = {}
    seq: list[tuple[int, str]] = []
    vectorize = "vmap" if batch_mode == "vmap" else "scan"
    # dims() parses LIBSVM files — resolve once per distinct DataSpec
    dims_cache: dict = {}

    for i, spec in enumerate(specs):
        algo = get_algorithm(spec.algorithm)
        backend = get_backend(spec.backend)
        check_spec(spec, algo, backend)
        if batch_mode == "never":
            seq.append((i, "batch='never'"))
            continue
        blockers = _batch_blockers(spec, algo, backend)
        if not blockers:
            if spec.data not in dims_cache:
                dims_cache[spec.data] = spec.data.dims()
            dims = dims_cache[spec.data]
            batch_groups.setdefault(
                _group_key(spec, resolved_alpha(spec, dims[0]), vectorize, dims),
                [],
            ).append(i)
        elif spec.backend in _POOL_WIDTH:
            pool_groups.setdefault(spec.backend, []).append(i)
        else:
            seq.append((i, "; ".join(blockers)))

    plans: list[_Plan] = []
    for key, idxs in batch_groups.items():
        if len(idxs) == 1:
            # a one-spec "batch" would pay switch/map overhead for nothing
            seq.append((idxs[0], "only spec in its batch group"))
            continue
        plans.append(_Plan("batch", idxs, reason=f"group key {key[:3]}..."))
    for backend_name, idxs in pool_groups.items():
        plans.append(_Plan("pool", idxs, reason=backend_name))

    # warm-start session reuse: fallback specs identical except `rounds` run
    # as ONE session stepped to each round count in ascending order (skipped
    # under batch="never", which promises per-spec timing)
    if batch_mode != "never":
        warm_groups: dict = {}
        for i, reason in seq:
            key = _warm_key(specs[i])
            if key is not None:
                warm_groups.setdefault(key, []).append(i)
        warmed: set[int] = set()
        for key, idxs in warm_groups.items():
            if len(idxs) < 2:
                continue
            idxs.sort(key=lambda i: specs[i].rounds)
            warmed.update(idxs)
            plans.append(_Plan("warm", idxs, reason="rounds-prefix group"))
            log.append(
                f"warm-start session reuse: specs {idxs} differ only in "
                f"rounds {[specs[i].rounds for i in idxs]} — one session, "
                "reports emitted at each prefix"
            )
        seq = [(i, reason) for i, reason in seq if i not in warmed]

    for i, reason in seq:
        plans.append(_Plan("seq", [i], reason=reason))
        if batch_mode != "never":
            log.append(f"spec[{i}]: fallback to sequential solve() — {reason}")
    return plans, log


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------


def _stack_states(states):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def _run_batched_group(
    specs: Sequence, idxs: list[int], z_for, vectorize: str, log: list[str]
) -> list[RunReport]:
    """Run one shape-compatible group as a single compiled program."""
    from repro.launch.mesh import make_sweep_mesh, sweep_mesh_devices

    group = [specs[i] for i in idxs]
    algo = get_algorithm(group[0].algorithm)
    d, _, _ = group[0].data.dims()
    from repro.compressors import get_compressor
    from repro.linalg import triu_size

    t = triu_size(d)
    # compressor branch table, ordered by first occurrence in the group
    branch_keys: list[tuple[str, int]] = []
    comp_idx: list[int] = []
    for spec in group:
        cfg = spec.fednl_config()
        bk = (cfg.compressor, cfg.k_for(d))
        if bk not in branch_keys:
            branch_keys.append(bk)
        comp_idx.append(branch_keys.index(bk))
    comps = [get_compressor(name, t, k) for name, k in branch_keys]
    cfg0 = group[0].fednl_config()
    alpha = resolved_alpha(group[0], d)
    body = algo.make_batch_round(cfg0, comps, alpha)

    t0 = time.perf_counter()
    zs = [z_for(spec) for spec in group]
    shared_z = all(spec.data == group[0].data for spec in group)
    state0 = _stack_states(
        [
            algo.init(z, spec.fednl_config(), x0=None, seed=spec.seed)
            for spec, z in zip(group, zs)
        ]
    )
    ci = jnp.asarray(comp_idx)
    rounds = group[0].rounds
    n_batch = len(group)

    if shared_z:
        z_const = zs[0]

        def spec_axis_map(ci_b, st_b):
            if vectorize == "vmap":
                return jax.vmap(body, in_axes=(None, 0, 0))(z_const, ci_b, st_b)
            return jax.lax.map(lambda a: body(z_const, *a), (ci_b, st_b))

        operands = (ci, state0)
    else:
        z_b = jnp.stack(zs)

        def spec_axis_map(z_bb, ci_b, st_b):
            if vectorize == "vmap":
                return jax.vmap(body)(z_bb, ci_b, st_b)
            return jax.lax.map(lambda a: body(*a), (z_bb, ci_b, st_b))

        operands = (z_b, ci, state0)

    def program(*args):
        st_b = args[-1]
        rest = args[:-1]

        def step(carry, _):
            return spec_axis_map(*rest, carry)

        return jax.lax.scan(step, st_b, None, length=rounds)

    n_dev = sweep_mesh_devices(n_batch)
    if n_dev > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = make_sweep_mesh(n_dev)
        program = shard_map(
            program,
            mesh=mesh,
            in_specs=tuple(P("sweep") for _ in operands),
            out_specs=(P("sweep"), P(None, "sweep")),
        )

    run = jax.jit(program)
    compiled = run.lower(*operands).compile()  # compile outside the timed loop
    init_s = time.perf_counter() - t0

    t1 = time.perf_counter()
    final_state, metrics = compiled(*operands)
    jax.block_until_ready(final_state)
    wall = time.perf_counter() - t1

    log.append(
        f"batched {n_batch} specs as one program: {group[0].algorithm}, "
        f"{len(comps)} compressor branch(es), {rounds} rounds, "
        f"vectorize={vectorize}, devices={n_dev} "
        f"(compile {init_s:.2f}s, run {wall:.2f}s)"
    )

    # materialize per-spec reports from the (rounds, batch) metric arrays
    cols = {
        name: np.asarray(getattr(metrics, name))
        for name in metrics._fields
    }
    x_final = np.asarray(final_state.x)
    reports = []
    for b, spec in enumerate(group):
        records = [
            RoundRecord(
                round=r,
                grad_norm=float(cols["grad_norm"][r, b]),
                f=float(cols["f"][r, b]),
                l=float(cols["l"][r, b]),
                sent_elems=int(cols["sent_elems"][r, b]),
                sent_bits=int(cols["sent_bits"][r, b]),
                sent_bits_payload=int(cols["sent_bits_payload"][r, b]),
                sent_bits_wire=int(cols["sent_bits_wire"][r, b]),
                ls_steps=(
                    int(cols["ls_steps"][r, b]) if "ls_steps" in cols else None
                ),
            )
            for r in range(rounds)
        ]
        reports.append(
            RunReport(
                spec=spec,
                algorithm=spec.algorithm,
                backend=spec.backend,
                x=x_final[b],
                records=records,
                rounds=rounds,
                wall_time_s=wall / n_batch,
                init_time_s=init_s / n_batch,
                extras={
                    "sweep_batched": True,
                    "batch_size": n_batch,
                    "batch_wall_time_s": wall,
                    "batch_init_time_s": init_s,
                    "vectorize": vectorize,
                    "devices": n_dev,
                    "compressor_branch": branch_keys[comp_idx[b]][0],
                },
            )
        )
    return reports


# ---------------------------------------------------------------------------
# the sweep driver
# ---------------------------------------------------------------------------


def run_sweep(specs: Sequence, batch_mode: str, sweep: Any = None) -> SweepReport:
    from repro.api.facade import solve

    t_start = time.perf_counter()
    plans, log = plan_sweep(specs, batch_mode)
    vectorize = "vmap" if batch_mode == "vmap" else "scan"

    # one data build per distinct DataSpec across the whole sweep
    z_cache: dict[Any, Any] = {}

    def z_for(spec):
        if spec.data not in z_cache:
            z_cache[spec.data] = spec.data.build()
        return z_cache[spec.data]

    reports: list[RunReport | None] = [None] * len(specs)
    batched_specs = 0
    for plan in plans:
        if plan.kind == "batch":
            group_reports = _run_batched_group(
                specs, plan.indices, z_for, vectorize, log
            )
            for i, rep in zip(plan.indices, group_reports):
                reports[i] = rep
            batched_specs += len(plan.indices)
        elif plan.kind == "warm":
            from repro.api.session import open_session

            # one session for the whole rounds-prefix group: step to each
            # spec's round count (ascending) and report it there — step
            # composability makes every report bit-identical to its own
            # solve() while the shared prefix is computed once
            spec_max = specs[plan.indices[-1]]
            with open_session(spec_max, z=z_for(spec_max)) as session:
                for i in plan.indices:
                    session.step(specs[i].rounds - session.round)
                    reports[i] = session.report(spec=specs[i])
        elif plan.kind == "pool":
            width = min(_POOL_WIDTH[plan.reason], len(plan.indices))
            log.append(
                f"pool: {len(plan.indices)} specs on {plan.reason} via "
                f"{width} worker thread(s)"
            )
            with ThreadPoolExecutor(max_workers=width) as pool:
                futures = [
                    pool.submit(
                        solve,
                        specs[i],
                        z=(
                            z_for(specs[i])
                            if get_backend(specs[i].backend).needs_problem
                            else None
                        ),
                    )
                    for i in plan.indices
                ]
                for i, fut in zip(plan.indices, futures):
                    reports[i] = fut.result()
        else:
            for i in plan.indices:
                spec = specs[i]
                z = (
                    z_for(spec)
                    if get_backend(spec.backend).needs_problem
                    else None
                )
                reports[i] = solve(spec, z=z)

    wall = time.perf_counter() - t_start
    return SweepReport(
        specs=tuple(specs),
        reports=reports,  # type: ignore[arg-type]
        log=log,
        wall_time_s=wall,
        sweep=sweep,
        extras={
            "batch_mode": batch_mode,
            "batched_specs": batched_specs,
            "n_groups": len(plans),
            "n_data_builds": len(z_cache),
        },
    )
