"""Pluggable strategy registries: algorithm x backend x compressor.

The facade is extension-by-registration (the factorization FL frameworks
argue for — algorithm family and execution backend vary independently):

  * :func:`register_algorithm` — an :class:`Algorithm` bundles the round
    builder + state init the *local* and *sharded* execution strategies
    consume, plus the capability flags wire backends use to decide whether
    they speak its protocol;
  * :func:`register_backend` — a :class:`Backend` strategy object turns
    ``(spec, algorithm, problem)`` into a :class:`RunReport`;
  * :func:`register_compressor` — inserts a ``(T, k) -> Compressor`` factory
    into the shared ``repro.compressors`` registry every backend reads.

Built-ins self-register on first lookup (``repro.api.backends`` import), so
``import repro.api`` stays cheap and cycle-free.
"""

from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A registered FedNL-family algorithm.

    ``kind`` fixes the metrics/protocol shape:
      "full"  every round reports (grad_norm, f, l) — Algorithms 1/2;
      "pp"    partial participation: rounds report (x, l); the gradient is a
              post-run diagnostic — Algorithm 3.

    ``init(z, cfg, x0, seed) -> state`` and
    ``make_round(z, cfg, tau) -> round_fn`` are the jittable pieces the
    simulation-style backends drive (``tau`` is ignored by "full"
    algorithms).  Wire backends (star-*) implement their own client/master
    event loops and consult only ``kind``/``line_search``.

    ``make_batch_round(cfg, comps, alpha) -> body(z, comp_idx, state)`` is
    the optional sweep-batching hook: given the group-shared config, the
    group's compressor table and the shared resolved alpha, it returns a
    round body the ``solve_many`` engine maps over a stacked spec axis
    (see ``repro.core.fednl_batch``).  Algorithms without it (``None``)
    always take the per-spec fallback path in a sweep — never an error.
    """

    name: str
    kind: str  # "full" | "pp"
    init: Callable
    make_round: Callable
    line_search: bool = False
    make_batch_round: Callable | None = None

    def __post_init__(self):
        if self.kind not in ("full", "pp"):
            raise ValueError(f"unknown algorithm kind {self.kind!r}")


class SessionHandle:
    """Round-granular execution driver a :meth:`Backend.open` returns.

    A handle owns one live run: the compiled/connected round machinery plus
    the evolving algorithm state.  ``repro.api.session.Session`` drives it;
    nothing else should.  Contract (the DESIGN.md §10 numerics bar):
    ``step_rounds(k)`` followed by ``step_rounds(m)`` must produce the same
    state and records, bit for bit, as ``step_rounds(k + m)`` — backends are
    free to execute each call as one chunked segment (deferred host sync),
    but never to make the trajectory depend on the chunking.
    """

    #: rounds executed so far (monotone; a restored handle starts at the
    #: checkpoint's round index, not 0)
    round: int = 0
    #: seconds spent building/compiling/handshaking before the first round
    init_time_s: float = 0.0
    #: cumulative seconds spent inside step_rounds (the solve-loop clock)
    wall_time_s: float = 0.0

    def step_rounds(self, n: int) -> list:
        """Advance ``n`` rounds; return one RoundRecord per round executed."""
        raise NotImplementedError

    def snapshot(self) -> tuple[dict, dict]:
        """Serializable backend state: ``(meta, arrays)`` — JSON-able scalars
        and name -> numpy array.  Must capture everything needed to resume
        bit-identically (model x, Hessian estimate/shift state, PRNG spine,
        round index); accumulated records live in the Session, not here."""
        raise NotImplementedError

    def finalize(self) -> dict:
        """Report tail for the CURRENT state: ``{"x": ndarray}`` plus
        optional ``"extras"`` / ``"final_grad_norm_fn"``.  Must be callable
        repeatedly (after any number of steps) without advancing state."""
        raise NotImplementedError

    def close(self) -> None:
        """Release transports/processes.  Idempotent."""


class Backend:
    """Execution-strategy interface: wraps an existing driver, returns RunReport.

    Subclasses implement :meth:`open` (returning a :class:`SessionHandle`,
    with ``supports_sessions = True``) or the legacy run-to-completion
    :meth:`run`; ``supports`` declares which algorithms the backend can
    execute (wire backends only speak the protocols they implement).
    ``needs_problem`` is False for backends whose workers rebuild the data
    themselves (star-tcp: nothing crosses the wire).
    """

    name: str = "?"
    needs_problem: bool = True
    # capability flags the facade checks so unsupported spec fields fail
    # loudly instead of being silently ignored (extensible per backend)
    supports_faults: bool = False  # transport-level dropout/straggler injection
    supports_x0: bool = False  # accepts an initial-iterate override
    supports_sessions: bool = False  # implements open() -> SessionHandle
    # non-trivial TopologySpec / MembershipSpec (repro.comm.topology): only
    # the wire backends route uplinks through aggregators or elastic cohorts
    supports_topology: bool = False

    def supports(self, algo: Algorithm) -> bool:
        return True

    def open(self, spec, algo: Algorithm, z, x0, restore=None) -> SessionHandle:
        raise NotImplementedError(
            f"backend {self.name!r} does not implement the Session protocol "
            "(open); use solve(spec) / Backend.run"
        )

    def run(self, spec, algo: Algorithm, z, x0):
        """Run-to-completion entry.  Session-capable backends inherit this
        open -> run -> close composition; legacy backends override it."""
        if not self.supports_sessions:
            raise NotImplementedError
        from repro.api.session import Session

        with Session(spec, algo, self, self.open(spec, algo, z, x0)) as s:
            return s.run()


class Registry:
    """A named string -> strategy map with lazy built-in population."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, object] = {}

    def register(self, name: str, entry, *, overwrite: bool = False) -> None:
        # load builtins first so user registrations always layer on top of
        # them — registering (or overwriting) a builtin name before the
        # first lookup must not make the lazy builtin import collide later
        _ensure_builtins()
        if not overwrite and name in self._entries:
            raise ValueError(f"{self.kind} {name!r} already registered")
        self._entries[name] = entry

    def get(self, name: str):
        _ensure_builtins()
        if name not in self._entries:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{sorted(self._entries)}"
            )
        return self._entries[name]

    def names(self) -> list[str]:
        _ensure_builtins()
        return sorted(self._entries)


ALGORITHMS = Registry("algorithm")
BACKENDS = Registry("backend")

_builtins_loaded = False


def _ensure_builtins() -> None:
    global _builtins_loaded
    if not _builtins_loaded:
        # set before the import as a re-entrancy guard (backends.py calls
        # register_* at module level), but on failure reset the flag AND roll
        # back partial registrations: a transient import error must not
        # poison the registries — the retry re-executes the module top level
        # (Python drops failed imports from sys.modules), so leftovers would
        # turn every later lookup into 'already registered'
        _builtins_loaded = True
        before = {r: set(r._entries) for r in (ALGORITHMS, BACKENDS)}
        try:
            # registers the built-in algorithms and backends on import
            import repro.api.backends  # noqa: F401
        except BaseException:
            _builtins_loaded = False
            for reg, names in before.items():
                for leftover in set(reg._entries) - names:
                    del reg._entries[leftover]
            raise


def register_algorithm(algo: Algorithm, *, overwrite: bool = False) -> Algorithm:
    ALGORITHMS.register(algo.name, algo, overwrite=overwrite)
    return algo


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    BACKENDS.register(backend.name, backend, overwrite=overwrite)
    return backend


def register_compressor(
    name: str, make: Callable, *, overwrite: bool = False
) -> None:
    """Register a ``(T, k) -> Compressor`` factory under ``name`` in the
    shared compressor registry (visible to every algorithm and backend,
    including the legacy ``get_compressor`` path)."""
    from repro.compressors.core import COMPRESSORS
    from repro.compressors.core import CompressorSpec as _CoreCompressorSpec

    if not overwrite and name in COMPRESSORS:
        raise ValueError(f"compressor {name!r} already registered")
    COMPRESSORS[name] = _CoreCompressorSpec(name, make)


def get_algorithm(name: str) -> Algorithm:
    return ALGORITHMS.get(name)


def get_backend(name: str) -> Backend:
    return BACKENDS.get(name)


def list_algorithms() -> list[str]:
    return ALGORITHMS.names()


def list_backends() -> list[str]:
    return BACKENDS.names()
