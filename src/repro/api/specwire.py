"""Versioned wire serialization of :class:`~repro.api.spec.ExperimentSpec`.

The gateway RPC layer (``repro.gateway``) ships specs between processes that
may run different builds of this repo, so the encoding is explicit about its
version and *loud* about anything it does not understand: an unknown field
anywhere in the payload — top level or nested (``data``, ``compressor``,
``fault``, ``topology``, ``membership``) — is rejected with an error naming
the exact dotted field, never silently dropped.  Silently ignoring a field
would run an experiment the submitter did not describe, which breaks the
bit-identity contract before a single round executes.

Encoding: canonical JSON (sorted keys, no whitespace) of
``{"spec_wire_version": 1, "spec": spec_to_dict(spec)}``.  Python floats
round-trip exactly through ``json`` (repr is shortest-round-trip), so every
float hyper-parameter — lam, mu, ls_c, k_multiplier, fault probabilities —
is bit-identical after decode; trajectories therefore are too.

``decode_spec`` is strict in both directions of version skew: a payload
with a *newer* version is refused (fields this build cannot validate), and
a payload with unknown fields under the current version is refused
field-by-field.  Run control that must not cross the wire (callables,
pre-built problem arrays) never appears here by construction — the spec is
data-only.
"""

from __future__ import annotations

import dataclasses
import json

from repro.api.spec import ExperimentSpec

SPEC_WIRE_VERSION = 1

_VERSION_KEY = "spec_wire_version"


def _known_fields(cls) -> set[str]:
    return {f.name for f in dataclasses.fields(cls)}


def _reject_unknown(d: dict, cls, prefix: str) -> None:
    """Raise ValueError naming every key of ``d`` that is not a field of the
    dataclass ``cls`` (dotted with ``prefix`` for nested payload sections)."""
    if not isinstance(d, dict):
        raise ValueError(
            f"spec wire payload: {prefix or 'spec'} must be an object, got "
            f"{type(d).__name__}"
        )
    unknown = sorted(set(d) - _known_fields(cls))
    if unknown:
        named = ", ".join(f"{prefix}{u}" for u in unknown)
        raise ValueError(
            f"spec wire payload has unknown field(s): {named} (this build "
            f"speaks spec_wire_version {SPEC_WIRE_VERSION}; known "
            f"{prefix or 'spec.'}fields: "
            f"{', '.join(sorted(_known_fields(cls)))})"
        )


def encode_spec(spec: ExperimentSpec) -> bytes:
    """Serialize ``spec`` for the wire (canonical versioned JSON bytes)."""
    from repro.api.session import spec_to_dict

    if not isinstance(spec, ExperimentSpec):
        raise TypeError(
            f"encode_spec takes an ExperimentSpec, got {type(spec).__name__}"
        )
    payload = {_VERSION_KEY: SPEC_WIRE_VERSION, "spec": spec_to_dict(spec)}
    return json.dumps(payload, sort_keys=True, separators=(",", ":")).encode()


def decode_spec_dict(payload: dict) -> ExperimentSpec:
    """Decode an already-parsed wire payload dict (see :func:`decode_spec`)."""
    from repro.api.session import spec_from_dict
    from repro.api.spec import CompressorSpec, DataSpec
    from repro.comm.transport import FaultSpec

    if not isinstance(payload, dict) or _VERSION_KEY not in payload:
        raise ValueError(
            f"spec wire payload missing {_VERSION_KEY!r} (not a "
            "repro.api.specwire encoding?)"
        )
    version = payload[_VERSION_KEY]
    if version != SPEC_WIRE_VERSION:
        raise ValueError(
            f"spec wire payload is version {version!r}; this build speaks "
            f"version {SPEC_WIRE_VERSION} only (a newer encoding may carry "
            "fields this build cannot validate — upgrade, don't guess)"
        )
    extra = sorted(set(payload) - {_VERSION_KEY, "spec"})
    if extra:
        raise ValueError(
            f"spec wire payload has unknown top-level key(s): "
            f"{', '.join(extra)}"
        )
    d = payload.get("spec")
    _reject_unknown(d, ExperimentSpec, "")
    if "data" in d:
        _reject_unknown(d["data"], DataSpec, "data.")
    if "compressor" in d:
        _reject_unknown(d["compressor"], CompressorSpec, "compressor.")
    if d.get("fault") is not None:
        _reject_unknown(d["fault"], FaultSpec, "fault.")
    if d.get("topology") is not None or d.get("membership") is not None:
        from repro.comm.topology import (
            MembershipEvent,
            MembershipSpec,
            TopologySpec,
        )

        if d.get("topology") is not None:
            _reject_unknown(d["topology"], TopologySpec, "topology.")
        if d.get("membership") is not None:
            _reject_unknown(d["membership"], MembershipSpec, "membership.")
            for i, ev in enumerate(d["membership"].get("events", ())):
                _reject_unknown(
                    ev, MembershipEvent, f"membership.events[{i}]."
                )
    # spec_from_dict rebuilds nested dataclasses; ExperimentSpec.__post_init__
    # then re-runs the full field validation exactly as a local construction
    return spec_from_dict(d)


def decode_spec(data: bytes) -> ExperimentSpec:
    """Inverse of :func:`encode_spec`; rejects unknown versions and unknown
    fields loudly (module docstring)."""
    try:
        payload = json.loads(data.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise ValueError(f"spec wire payload is not valid JSON: {exc}") from exc
    return decode_spec_dict(payload)
