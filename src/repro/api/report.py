"""The one result type every backend returns: RunReport.

Replaces the per-variant result zoo (``RunResult`` / ``PPRunResult`` /
``StarRunResult`` / ``StarPPRunResult`` — kept as deprecated shims) with a
single streaming record: one :class:`RoundRecord` per round carrying the
metrics *every* algorithm/backend pair can report (grad norm, f, l, sent
bits under BOTH accounting models, participation), plus backend-specific
measurements in ``extras``.

Fields an algorithm does not expose are ``None`` rather than faked: FedNL-PP
never computes the global gradient per round (doing so would defeat partial
participation), so its records carry the iterate ``x`` and ``l`` instead and
``final_grad_norm`` is a single post-run diagnostic.

Bit-parity contract: for a spec that maps onto a legacy driver, the
``grad_norms`` / ``sent_bits`` / ``x_hist`` views reproduce that driver's
arrays bit-for-bit (tests/test_api.py pins this against the golden traces).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """Metrics of one communication round, as uniform as the algorithms allow."""

    round: int
    grad_norm: float | None = None  # None for PP (server never sees the gradient)
    f: float | None = None
    l: float | None = None
    sent_elems: int | None = None  # payload elements uplinked this round
    sent_bits: int = 0  # under the spec's accounting model (parity-critical)
    sent_bits_payload: int | None = None  # Section-7 payload model
    sent_bits_wire: int | None = None  # full framed uplink model
    ls_steps: int | None = None  # fednl-ls backtracking trials
    x: np.ndarray | None = None  # PP: the model the server produced this round
    participants: tuple[int, ...] | None = None  # PP: contributing client ids
    dropped: tuple[int, ...] | None = None  # PP: clients that dropped


@dataclasses.dataclass
class RunReport:
    """What solve(spec) returns: final model, per-round records, accounting."""

    spec: Any  # the ExperimentSpec that produced this run
    algorithm: str
    backend: str
    x: np.ndarray  # final model
    records: list[RoundRecord]
    rounds: int
    wall_time_s: float
    init_time_s: float
    # PP only: lazily evaluated post-run ||grad f(x)|| diagnostic (the server
    # never sees the gradient; star-tcp additionally has to rebuild the
    # problem to evaluate it, so the work runs on first access, not per solve)
    final_grad_norm_fn: Callable[[], float] | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def final_grad_norm(self) -> float | None:
        """Post-run ||grad f(x)||: the last recorded grad norm for
        full-participation runs, the (cached) lazy diagnostic for PP."""
        if "_final_grad_norm" not in self.__dict__:
            if self.final_grad_norm_fn is not None:
                self._final_grad_norm = float(self.final_grad_norm_fn())
                # drop the thunk once cached: its closure pins the problem
                # array, which must not live as long as the report does
                self.final_grad_norm_fn = None
            elif self.records and self.records[-1].grad_norm is not None:
                self._final_grad_norm = self.records[-1].grad_norm
            else:
                self._final_grad_norm = None
        return self._final_grad_norm

    # --- array views (the legacy result-dataclass fields) -----------------

    def _column(self, name: str) -> np.ndarray:
        return np.asarray([getattr(r, name) for r in self.records])

    @property
    def grad_norms(self) -> np.ndarray:
        return self._column("grad_norm")

    @property
    def f_vals(self) -> np.ndarray:
        return self._column("f")

    @property
    def l_vals(self) -> np.ndarray:
        return self._column("l")

    @property
    def sent_bits(self) -> np.ndarray:
        return self._column("sent_bits")

    @property
    def sent_bits_payload(self) -> np.ndarray:
        return self._column("sent_bits_payload")

    @property
    def sent_bits_wire(self) -> np.ndarray:
        return self._column("sent_bits_wire")

    @property
    def x_hist(self) -> np.ndarray:
        """(rounds, d) per-round iterates (PP backends)."""
        return np.asarray([r.x for r in self.records])

    @property
    def participants(self) -> list[list[int]]:
        return [list(r.participants or ()) for r in self.records]

    @property
    def dropped(self) -> list[list[int]]:
        return [list(r.dropped or ()) for r in self.records]

    def summary(self) -> str:
        """One-line human summary (what the CLI entrypoints print).

        Deliberately cheap: reports the PP grad diagnostic only if a caller
        already evaluated it — never forces the lazy compute (which may
        rebuild the whole problem on star-tcp)."""
        gn_cached = self.__dict__.get("_final_grad_norm")
        gn = (
            f"||grad||={self.records[-1].grad_norm:.3e}"
            if self.records and self.records[-1].grad_norm is not None
            else f"||grad(x_final)||={gn_cached:.3e}"
            if gn_cached is not None
            else "||grad||=n/a"
        )
        mb = float(np.sum(self.sent_bits)) / 8e6 if self.records else 0.0
        return (
            f"{self.algorithm}@{self.backend}: rounds={self.rounds} {gn} "
            f"uplink={mb:.2f} MB ({self.spec.accounting}) "
            f"solve={self.wall_time_s:.2f}s init={self.init_time_s:.2f}s"
        )


class RunReportBuilder:
    """Incremental :class:`RunReport` construction (the Session path).

    Records stream in one round at a time (``add``) or in chunked segments
    (``extend``); ``build`` closes over the *current* tail — final model,
    extras, the lazy PP grad diagnostic — so a session can emit a report
    mid-run, keep stepping, and emit another.  Each build snapshots the
    record list; later adds never mutate an already-built report.
    """

    def __init__(self, spec: Any, algorithm: str, backend: str):
        self.spec = spec
        self.algorithm = algorithm
        self.backend = backend
        self.records: list[RoundRecord] = []

    def add(self, record: RoundRecord) -> RoundRecord:
        self.records.append(record)
        return record

    def extend(self, records: list[RoundRecord]) -> list[RoundRecord]:
        self.records.extend(records)
        return records

    def build(
        self,
        x: np.ndarray,
        wall_time_s: float,
        init_time_s: float,
        final_grad_norm_fn: Callable[[], float] | None = None,
        extras: dict[str, Any] | None = None,
        spec: Any = None,
    ) -> RunReport:
        """Materialize a report from the records so far.  ``spec`` optionally
        relabels the report (sweep warm-start reuse emits one report per
        rounds-prefix spec from a single session)."""
        return RunReport(
            spec=self.spec if spec is None else spec,
            algorithm=self.algorithm,
            backend=self.backend,
            x=np.asarray(x),
            records=list(self.records),
            rounds=len(self.records),
            wall_time_s=wall_time_s,
            init_time_s=init_time_s,
            final_grad_norm_fn=final_grad_norm_fn,
            extras=dict(extras) if extras else {},
        )


def _spec_get(spec: Any, path: str) -> Any:
    """Resolve a dotted field path on a spec ('compressor.name', 'data.seed')."""
    value = spec
    for part in path.split("."):
        value = getattr(value, part)
    return value


@dataclasses.dataclass
class SweepReport:
    """What ``solve_many`` returns: one RunReport per spec, in expansion
    order, plus the engine's dispatch log and aggregation helpers.

    ``log`` records every grouping/fallback decision (a spec that cannot
    batch is run per-spec and logged — never silently dropped).
    """

    specs: tuple[Any, ...]  # the expanded ExperimentSpecs, expansion order
    reports: list[RunReport]
    log: list[str]
    wall_time_s: float
    sweep: Any = None  # the SweepSpec, when solve_many was given one
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.reports)

    def __iter__(self):
        return iter(self.reports)

    def __getitem__(self, i: int) -> RunReport:
        return self.reports[i]

    # --- aggregation helpers ---------------------------------------------

    def group_by(self, *fields: str) -> dict[tuple, list[RunReport]]:
        """Group reports by spec field paths, preserving expansion order
        within each group: ``report.group_by("compressor.name")``."""
        out: dict[tuple, list[RunReport]] = {}
        for spec, rep in zip(self.specs, self.reports):
            key = tuple(_spec_get(spec, f) for f in fields)
            out.setdefault(key, []).append(rep)
        return out

    def table(self, *fields: str) -> list[dict[str, Any]]:
        """One summary row per spec: the requested spec fields plus the
        metrics every run reports (rounds, final grad norm where the
        algorithm exposes it, total uplink bits, wall time)."""
        rows = []
        for spec, rep in zip(self.specs, self.reports):
            row: dict[str, Any] = {f: _spec_get(spec, f) for f in fields}
            last = rep.records[-1] if rep.records else None
            row.update(
                rounds=rep.rounds,
                grad_norm=(last.grad_norm if last is not None else None),
                sent_bits_total=int(np.sum(rep.sent_bits)) if rep.records else 0,
                wall_time_s=rep.wall_time_s,
            )
            rows.append(row)
        return rows

    def round_table(self, column: str) -> np.ndarray:
        """(n_specs, max_rounds) per-round metric table (``grad_norm``,
        ``sent_bits``, ``f``, ...); shorter runs are padded with NaN."""
        width = max((rep.rounds for rep in self.reports), default=0)
        out = np.full((len(self.reports), width), np.nan)
        for i, rep in enumerate(self.reports):
            vals = [getattr(r, column) for r in rep.records]
            out[i, : len(vals)] = [
                np.nan if v is None else float(v) for v in vals
            ]
        return out

    def summary(self) -> str:
        batched = self.extras.get("batched_specs", 0)
        return (
            f"sweep: {len(self.reports)} specs in {self.wall_time_s:.2f}s "
            f"({len(self.reports) / self.wall_time_s:.1f} specs/s; "
            f"{batched} batched, {len(self.reports) - batched} fallback, "
            f"{self.extras.get('n_groups', 0)} groups)"
            if self.wall_time_s > 0
            else f"sweep: {len(self.reports)} specs"
        )
