"""solve(spec) / solve_many(sweep): the two entry points of the repro.

``solve`` validates one spec against the registries, builds (or accepts) the
federated problem, dispatches to the backend strategy, and returns the
unified :class:`RunReport`.  ``solve_many`` does the same for a whole
:class:`SweepSpec` grid, grouping compatible specs into single compiled
programs (``repro.api.batch``) and returning a :class:`SweepReport`.
Everything an entry script used to re-plumb — config projection, compressor
choice, bits accounting, metrics collection — happens behind these calls.
"""

from __future__ import annotations

from typing import Iterable

import jax

from repro.api.registry import Algorithm, Backend, get_algorithm, get_backend
from repro.api.report import RunReport, SweepReport
from repro.api.spec import ExperimentSpec
from repro.api.sweep import SweepSpec


def check_spec(
    spec: ExperimentSpec, algo: Algorithm, backend: Backend, *, z=None, x0=None
) -> None:
    """The capability checks both entry points share — a spec that would
    fail ``solve()`` fails ``solve_many()`` identically, before anything
    runs."""
    if not backend.supports(algo):
        raise ValueError(
            f"backend {backend.name!r} does not support algorithm "
            f"{algo.name!r} (it only speaks the protocols it implements)"
        )
    if x0 is not None and not backend.supports_x0:
        raise ValueError(
            f"backend {backend.name!r} does not support an x0 override (the "
            "wire protocols start every run from the INIT broadcast of the "
            "zero iterate)"
        )
    if spec.fault is not None and not backend.supports_faults:
        raise ValueError(
            f"backend {backend.name!r} cannot inject faults; a FaultSpec "
            "needs a wire backend (star-loopback / star-tcp) — running it "
            "fault-free here would silently change the experiment"
        )
    if z is not None and not backend.needs_problem:
        raise ValueError(
            f"backend {backend.name!r} rebuilds the problem from spec.data in "
            "its worker processes; a pre-built z cannot be shipped to it"
        )
    topo_live = spec.topology is not None and not spec.topology.trivial
    mem_live = spec.membership is not None and not spec.membership.trivial
    if (topo_live or mem_live) and not backend.supports_topology:
        what = "topology" if topo_live else "membership"
        raise ValueError(
            f"backend {backend.name!r} cannot run a non-trivial {what} spec; "
            "trees, async aggregation and membership events need a wire "
            "backend (star-loopback / star-tcp) — running the flat sync "
            "star here would silently change the experiment"
        )


def solve(spec: ExperimentSpec, z=None, x0=None) -> RunReport:
    """Run one experiment described by ``spec``.

    A thin wrapper over the Session protocol: ``open_session(spec).run()``
    under the spec's rounds/tol — bit-identical to the historical monolithic
    drivers (pinned by tests/test_api.py against the golden traces).  Use
    :func:`repro.api.open_session` directly to step rounds incrementally,
    observe records as they stream, or checkpoint/resume the run.

    ``z`` optionally supplies a pre-built problem array ``(n_clients, n_i, d)``
    — e.g. LM backbone features (examples/fednl_probe.py) or a LIBSVM
    round-trip — overriding ``spec.data``.  ``x0`` optionally overrides the
    zero initial iterate (local backend only; the wire protocols start every
    run from the INIT broadcast of the zero iterate).
    """
    # FedNL is an FP64 algorithm end-to-end; idempotent when already enabled
    jax.config.update("jax_enable_x64", True)
    algo = get_algorithm(spec.algorithm)
    backend = get_backend(spec.backend)
    if backend.supports_sessions:
        # open_session runs the full validation (check_spec included) itself
        from repro.api.session import open_session

        with open_session(spec, z=z, x0=x0) as session:
            return session.run()
    # legacy run-to-completion backends (custom registrations without open())
    check_spec(spec, algo, backend, z=z, x0=x0)
    if z is None and backend.needs_problem:
        z = spec.data.build()
    return backend.run(spec, algo, z, x0)


def solve_many(sweep: SweepSpec | Iterable[ExperimentSpec]) -> SweepReport:
    """Run a whole sweep — a :class:`SweepSpec` grid (``spec.grid(...)``) or
    any iterable of specs — and return a :class:`SweepReport` with one
    :class:`RunReport` per spec in expansion order.

    On the local backend, shape-compatible full-participation specs are
    grouped and executed as ONE jitted scan program per group (bit-identical
    per-spec results, compressor variation via ``lax.switch``, spec axis
    sharded across local devices when available); wire-backend specs are
    dispatched through a bounded worker pool; everything else falls back to
    per-spec ``solve()`` — each decision recorded in ``SweepReport.log``.
    """
    jax.config.update("jax_enable_x64", True)
    from repro.api.batch import run_sweep

    if isinstance(sweep, SweepSpec):
        specs, batch_mode, sweep_obj = sweep.specs(), sweep.batch, sweep
    else:
        specs, batch_mode, sweep_obj = tuple(sweep), "auto", None
        for s in specs:
            if not isinstance(s, ExperimentSpec):
                raise TypeError(
                    f"solve_many takes a SweepSpec or ExperimentSpecs, got "
                    f"{type(s).__name__}"
                )
    if not specs:
        raise ValueError("empty sweep: nothing to solve")
    return run_sweep(specs, batch_mode, sweep_obj)
