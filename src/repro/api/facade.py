"""solve(spec): the one entry point of the repro.

Validates the spec against the registries, builds (or accepts) the federated
problem, dispatches to the backend strategy, and returns the unified
:class:`RunReport`.  Everything an entry script used to re-plumb — config
projection, compressor choice, bits accounting, metrics collection — happens
behind this call.
"""

from __future__ import annotations

import jax

from repro.api.registry import get_algorithm, get_backend
from repro.api.report import RunReport
from repro.api.spec import ExperimentSpec


def solve(spec: ExperimentSpec, z=None, x0=None) -> RunReport:
    """Run one experiment described by ``spec``.

    ``z`` optionally supplies a pre-built problem array ``(n_clients, n_i, d)``
    — e.g. LM backbone features (examples/fednl_probe.py) or a LIBSVM
    round-trip — overriding ``spec.data``.  ``x0`` optionally overrides the
    zero initial iterate (local backend only; the wire protocols start every
    run from the INIT broadcast of the zero iterate).
    """
    # FedNL is an FP64 algorithm end-to-end; idempotent when already enabled
    jax.config.update("jax_enable_x64", True)
    algo = get_algorithm(spec.algorithm)
    backend = get_backend(spec.backend)
    if not backend.supports(algo):
        raise ValueError(
            f"backend {backend.name!r} does not support algorithm "
            f"{algo.name!r} (it only speaks the protocols it implements)"
        )
    if x0 is not None and not backend.supports_x0:
        raise ValueError(
            f"backend {backend.name!r} does not support an x0 override (the "
            "wire protocols start every run from the INIT broadcast of the "
            "zero iterate)"
        )
    if spec.fault is not None and not backend.supports_faults:
        raise ValueError(
            f"backend {backend.name!r} cannot inject faults; a FaultSpec "
            "needs a wire backend (star-loopback / star-tcp) — running it "
            "fault-free here would silently change the experiment"
        )
    if z is not None and not backend.needs_problem:
        raise ValueError(
            f"backend {backend.name!r} rebuilds the problem from spec.data in "
            "its worker processes; a pre-built z cannot be shipped to it"
        )
    if z is None and backend.needs_problem:
        z = spec.data.build()
    return backend.run(spec, algo, z, x0)
