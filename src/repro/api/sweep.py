"""Declarative experiment sweeps: SweepSpec = base spec + axes.

A sweep is data, exactly like :class:`repro.api.ExperimentSpec` itself: a
frozen base spec plus an ordered tuple of (axis, values) pairs.  Expansion is
the cartesian product in declared order — deterministic, duplicate-free, and
validated through the same ``ExperimentSpec.__post_init__`` / registry
machinery as a hand-built spec, so an invalid axis value fails with exactly
the error ``solve()`` would raise.

``ExperimentSpec.grid(**axes)`` is the ergonomic constructor::

    sweep = ExperimentSpec(data=DataSpec(dataset="w8a")).grid(
        seed=range(4),
        compressor=["topk", "randseqk", "natural"],
    )
    report = solve_many(sweep)          # one compiled program per batch group

Axis names are ExperimentSpec field names, plus aliases that reach into the
nested specs (``compressor`` accepts bare names, ``k_multiplier`` /
``comp_alpha`` target the CompressorSpec, ``data`` / ``dataset`` /
``data_seed`` target the DataSpec).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterator

from repro.api.spec import CompressorSpec, DataSpec, ExperimentSpec

BATCH_MODES = ("auto", "vmap", "never")

_SPEC_FIELDS = {f.name for f in dataclasses.fields(ExperimentSpec)}


def _set_compressor(spec: ExperimentSpec, value: Any) -> ExperimentSpec:
    """Compressor axis: a bare name keeps the base k_multiplier/alpha."""
    if isinstance(value, CompressorSpec):
        return spec.replace(compressor=value)
    if isinstance(value, str):
        return spec.replace(
            compressor=dataclasses.replace(spec.compressor, name=value)
        )
    raise TypeError(
        f"compressor axis values must be str or CompressorSpec, got {value!r}"
    )


def _set_data(spec: ExperimentSpec, value: Any) -> ExperimentSpec:
    if not isinstance(value, DataSpec):
        raise TypeError(f"data axis values must be DataSpec, got {value!r}")
    return spec.replace(data=value)


# axis aliases that reach into the nested frozen specs
_NESTED_AXES = {
    "compressor": _set_compressor,
    "data": _set_data,
    "k_multiplier": lambda s, v: s.replace(
        compressor=dataclasses.replace(s.compressor, k_multiplier=float(v))
    ),
    "comp_alpha": lambda s, v: s.replace(
        compressor=dataclasses.replace(s.compressor, alpha=v)
    ),
    "dataset": lambda s, v: s.replace(
        data=dataclasses.replace(s.data, dataset=str(v), shape=None)
    ),
    "data_seed": lambda s, v: s.replace(
        data=dataclasses.replace(s.data, seed=int(v))
    ),
}


def _apply_axis(spec: ExperimentSpec, name: str, value: Any) -> ExperimentSpec:
    if name in _NESTED_AXES:
        return _NESTED_AXES[name](spec, value)
    return spec.replace(**{name: value})


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A frozen grid of experiments: base spec x cartesian axes.

    ``batch`` is the execution policy ``solve_many`` follows:
      auto   group compatible specs and run each group as one compiled
             scan-over-``lax.map`` program (bit-identical to sequential
             ``solve()``); wire backends dispatch through a bounded worker
             pool; everything else falls back per spec — logged, never
             silently dropped.
      vmap   like auto but the batched groups use ``jax.vmap`` over the spec
             axis — maximal accelerator throughput, ulp-level numerical
             divergence from the sequential path is possible (DESIGN.md §9).
      never  run every spec sequentially through ``solve()`` in expansion
             order (per-spec timing stays meaningful — what the benchmark
             tables use; also disables the warm-started session reuse of
             rounds-prefix fallback groups, see ``repro.api.batch``).
    """

    base: ExperimentSpec
    axes: tuple[tuple[str, tuple[Any, ...]], ...] = ()
    batch: str = "auto"

    def __post_init__(self):
        if self.batch not in BATCH_MODES:
            raise ValueError(
                f"unknown batch mode {self.batch!r}; use "
                f"{' | '.join(BATCH_MODES)}"
            )
        # normalize: tolerate lists/iterators from callers, store tuples
        object.__setattr__(
            self,
            "axes",
            tuple((name, tuple(values)) for name, values in self.axes),
        )
        seen_axes = set()
        for name, values in self.axes:
            if name not in _SPEC_FIELDS and name not in _NESTED_AXES:
                known = sorted(_SPEC_FIELDS | set(_NESTED_AXES))
                raise ValueError(
                    f"unknown sweep axis {name!r}; axes are ExperimentSpec "
                    f"fields or aliases: {', '.join(known)}"
                )
            if name in seen_axes:
                raise ValueError(f"duplicate sweep axis {name!r}")
            seen_axes.add(name)
            if not values:
                raise ValueError(f"sweep axis {name!r} has no values")
            if len(set(values)) != len(values):
                raise ValueError(
                    f"sweep axis {name!r} has duplicate values: {values!r}"
                )

    @property
    def n_specs(self) -> int:
        n = 1
        for _, values in self.axes:
            n *= len(values)
        return n

    def specs(self) -> tuple[ExperimentSpec, ...]:
        """Deterministic expansion: cartesian product, axes in declared order,
        values in given order (later axes vary fastest).  Each spec runs the
        full ``ExperimentSpec`` validation, so a bad combination fails here
        with the same error ``solve()`` raises on a hand-built spec."""
        out = []
        names = [name for name, _ in self.axes]
        for combo in itertools.product(*(values for _, values in self.axes)):
            spec = self.base
            for name, value in zip(names, combo):
                spec = _apply_axis(spec, name, value)
            out.append(spec)
        if len(set(out)) != len(out):
            # distinct axis values can still collide after normalization
            # (e.g. "topk" and CompressorSpec("topk") on the same axis)
            raise ValueError("sweep axes expand to duplicate specs")
        return tuple(out)

    def __iter__(self) -> Iterator[ExperimentSpec]:
        return iter(self.specs())

    def __len__(self) -> int:
        return self.n_specs

    def replace(self, **changes: Any) -> "SweepSpec":
        return dataclasses.replace(self, **changes)


def grid(base: ExperimentSpec, *, batch: str = "auto", **axes: Any) -> SweepSpec:
    """Build a :class:`SweepSpec` from keyword axes (``ExperimentSpec.grid``
    delegates here).  Axis order follows keyword order."""
    return SweepSpec(
        base=base,
        axes=tuple((name, tuple(values)) for name, values in axes.items()),
        batch=batch,
    )
