"""Declarative experiment description: one frozen dataclass per run.

``ExperimentSpec`` is the single configuration object of the repro —
algorithm x compressor x accounting x backend x faults in one value.  It is
deliberately *data only* (strings, numbers, nested frozen dataclasses): a
spec can be printed, hashed into a cache key, serialized with
``dataclasses.asdict``, swept over with ``dataclasses.replace``, and re-run
on a different execution backend by changing nothing but the ``backend``
field.  ``repro.api.solve`` turns a spec into a :class:`repro.api.RunReport`.

The algorithmic hyper-parameters map 1:1 onto :class:`FedNLConfig` (the
jit-level config the round builders consume); :meth:`ExperimentSpec.fednl_config`
performs that projection, so the facade never re-plumbs individual fields.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.comm.transport import FaultSpec
from repro.api.accounting import ACCOUNTINGS

# TopologySpec / MembershipSpec live in repro.comm.topology and are imported
# lazily (string annotations below): topology.py pulls the jax-heavy star
# stack, and `import repro.api` must stay cheap.

# named problem shapes live in repro.data.DATASET_SHAPES (paper Tables 1-3)


def _algorithm_kind(name: str) -> str | None:
    """Registered ``Algorithm.kind`` ("full" | "pp"), or None when unknown.

    Spec validation must not pre-empt solve()'s loud unknown-algorithm error,
    so unregistered names skip the kind-dependent checks here.  Consulting
    the registry (not a hard-coded name list) keeps ``register_algorithm``
    first-class: a custom kind="pp" algorithm gets tau/fault/tol validation
    identical to the built-in fednl-pp.
    """
    from repro.api.registry import ALGORITHMS

    try:
        return ALGORITHMS.get(name).kind
    except KeyError:
        return None


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Where the federated problem comes from.

    Exactly one source:
      * ``dataset`` — a named synthetic shape from ``repro.data.DATASET_SHAPES``
        (w8a / a9a / phishing / tiny), regenerated deterministically from
        ``seed`` (this is the only source the star-tcp backend supports:
        workers rebuild their shard from the seed, no data crosses the wire);
      * ``shape`` — an explicit ``(d, n_clients, n_i)`` synthetic instance;
      * ``libsvm`` — a real LIBSVM file on disk, partitioned into
        ``clients`` x ``per_client`` shards.

    ``seed`` drives both the synthetic generator and the u.a.r. reshuffle of
    ``partition_clients`` (the paper's preprocessing pipeline).
    """

    dataset: str = "tiny"
    shape: tuple[int, int, int] | None = None
    libsvm: str | None = None
    clients: int | None = None
    per_client: int | None = None
    seed: int = 0

    def dims(self) -> tuple[int, int, int]:
        """(d, n_clients, n_i) of the problem this spec builds."""
        if self.libsvm is not None:
            if self.clients is None or self.per_client is None:
                raise ValueError("libsvm data needs clients and per_client")
            from repro.data import parse_libsvm

            x, _ = parse_libsvm(self.libsvm)
            return x.shape[1] + 1, self.clients, self.per_client
        if self.shape is not None:
            return tuple(self.shape)
        from repro.data import DATASET_SHAPES

        return DATASET_SHAPES[self.dataset]

    def build(self):
        """Materialize z: (n_clients, n_i, d) label-absorbed design matrices."""
        import jax.numpy as jnp

        from repro.data import (
            DATASET_SHAPES,
            add_intercept,
            make_synthetic_logreg,
            parse_libsvm,
            partition_clients,
        )

        if self.libsvm is not None:
            if self.clients is None or self.per_client is None:
                raise ValueError("libsvm data needs clients and per_client")
            x, y = parse_libsvm(self.libsvm)
            n, n_i = self.clients, self.per_client
        else:
            name_or_dims = self.shape if self.shape is not None else self.dataset
            if isinstance(name_or_dims, str):
                _, n, n_i = DATASET_SHAPES[name_or_dims]
            else:
                _, n, n_i = name_or_dims
            x, y = make_synthetic_logreg(name_or_dims, seed=self.seed)
        return jnp.asarray(
            partition_clients(add_intercept(x), y, n, n_i, seed=self.seed)
        )


@dataclasses.dataclass(frozen=True)
class CompressorSpec:
    """Which compressor a spec runs, in paper units.

    ``name`` must be registered (six built-ins; ``repro.api.register_compressor``
    adds more).  ``k_multiplier`` is the paper's K = k_multiplier * d sparsity
    budget; ``alpha`` overrides the compressor-recommended Hessian learning
    rate (None keeps the scaled-form default of 1.0).
    """

    name: str = "topk"
    k_multiplier: float = 8.0
    alpha: float | None = None


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One declarative FedNL experiment: solve(spec) runs it anywhere.

    Backends (``repro.api.register_backend`` adds more):
      local          single-process simulation (vmapped clients, jitted round)
      sharded        shard_mapped clients across mesh devices
      star-loopback  full wire protocol over in-process loopback transport
      star-tcp       master + one OS process per client over TCP localhost

    Algorithms (``repro.api.register_algorithm`` adds more):
      fednl / fednl-ls / fednl-pp (Algorithms 1-3 of the paper).
    """

    # --- objective -------------------------------------------------------
    objective: str = "logreg"  # L2-regularized logistic regression
    lam: float = 1e-3  # L2 regularization strength
    data: DataSpec = dataclasses.field(default_factory=DataSpec)

    # --- algorithm -------------------------------------------------------
    algorithm: str = "fednl"  # registered name: fednl | fednl-ls | fednl-pp
    compressor: CompressorSpec = dataclasses.field(default_factory=CompressorSpec)
    option: str = "B"  # master step rule: "A" (projection) | "B" (l-shift)
    mu: float = 1e-3  # strong-convexity lower bound for Option A
    hess0: str = "exact"  # "exact" | "zero" H_i^0 initialization
    # Hessian SYRK implementation (DESIGN.md §12): "fused" (default) routes
    # through kernels.ops.hessian_fused — bit-identical to "jnp" for
    # d <= 128, documented ulp drift above; "jnp" is the single-dot_general
    # parity reference; "pallas" forces the Pallas wrapper (interpret mode
    # off-TPU — the kernel-validation path, not a CPU hot path)
    hessian: str = "fused"
    use_kernel: bool = False  # deprecated spelling of hessian="pallas"
    # line-search parameters (fednl-ls)
    ls_c: float = 0.49
    ls_gamma: float = 0.5
    ls_max_steps: int = 30
    ls_tol: float = 1e-12

    # --- participation (fednl-pp) ---------------------------------------
    tau: int | None = None  # sampled clients per round (None -> n // 2)
    on_dropout: str = "partial"  # "partial" | "resample" master fallback
    fault: FaultSpec | None = None  # dropout/straggler injection

    # --- topology + membership (repro.comm.topology) ---------------------
    # how uplinks reach the root: None/star = flat PR-1 star; tree inserts
    # AggregatorNodes; mode="async" bounds staleness instead of barriering
    topology: "TopologySpec | None" = None
    # declarative join/leave schedule (flat sync star, wire backends only)
    membership: "MembershipSpec | None" = None

    # --- accounting + execution backend ---------------------------------
    accounting: str = "payload"  # "payload" | "wire" sent_bits model
    backend: str = "local"  # registered backend name
    aggregate: str = "dense_psum"  # sharded collective: dense_psum | sparse_allgather
    devices: int | None = None  # sharded mesh size (None -> all local devices)
    host: str = "127.0.0.1"  # star-tcp bind address

    # --- run control -----------------------------------------------------
    rounds: int = 100
    # grad-norm early stop (0 = run all rounds).  Full-participation
    # algorithms only: the PP server never sees the global gradient, so a
    # nonzero tol on a PP spec is rejected rather than silently ignored.
    tol: float = 0.0
    seed: int = 0  # algorithm PRNG seed (client sampling + compression)

    def __post_init__(self):
        if self.objective != "logreg":
            raise ValueError(
                f"unknown objective {self.objective!r}; only 'logreg' is "
                "implemented (the paper's problem class)"
            )
        if self.accounting not in ACCOUNTINGS:
            raise ValueError(
                f"unknown accounting {self.accounting!r}; use "
                f"{' | '.join(ACCOUNTINGS)}"
            )
        if self.option not in ("A", "B"):
            raise ValueError(f"unknown option {self.option!r}; use 'A' | 'B'")
        if self.hess0 not in ("exact", "zero"):
            raise ValueError(f"unknown hess0 {self.hess0!r}")
        if self.hessian not in ("fused", "jnp", "pallas"):
            raise ValueError(
                f"unknown hessian {self.hessian!r}; use 'fused' | 'jnp' | "
                "'pallas'"
            )
        if self.on_dropout not in ("partial", "resample"):
            raise ValueError(f"unknown on_dropout {self.on_dropout!r}")
        if self.rounds < 0:
            raise ValueError("rounds must be >= 0")
        kind = _algorithm_kind(self.algorithm)
        needs_tau = kind == "pp"
        if kind == "full" and (self.tau is not None or self.fault is not None):
            raise ValueError(
                f"tau/fault only apply to partial participation, not "
                f"{self.algorithm!r}"
            )
        if needs_tau and self.tol > 0.0:
            raise ValueError(
                "tol-based early stopping is undefined for partial "
                "participation (the server never sees the global gradient); "
                "bound the run with rounds instead"
            )
        if self.topology is not None or self.membership is not None:
            from repro.comm.topology import MembershipSpec, TopologySpec

            if self.topology is not None and not isinstance(
                self.topology, TopologySpec
            ):
                raise TypeError(
                    f"topology must be a TopologySpec, got "
                    f"{type(self.topology).__name__}"
                )
            if self.membership is not None and not isinstance(
                self.membership, MembershipSpec
            ):
                raise TypeError(
                    f"membership must be a MembershipSpec, got "
                    f"{type(self.membership).__name__}"
                )
            topo_live = self.topology is not None and not self.topology.trivial
            mem_live = self.membership is not None and not self.membership.trivial
            if topo_live and mem_live:
                raise ValueError(
                    "membership events compose with the flat sync star only "
                    "(drop the non-trivial topology or the membership events)"
                )
            if (topo_live or mem_live) and kind == "pp":
                raise ValueError(
                    f"topology/membership do not compose with partial "
                    f"participation ({self.algorithm!r}): PP samples a "
                    "cohort per round already — spec one participation "
                    "model at a time"
                )

    # --- projections ------------------------------------------------------

    def fednl_config(self):
        """Project onto the jit-level :class:`repro.core.fednl.FedNLConfig`."""
        from repro.core.fednl import FedNLConfig

        return FedNLConfig(
            compressor=self.compressor.name,
            k_multiplier=self.compressor.k_multiplier,
            alpha=self.compressor.alpha,
            option=self.option,
            mu=self.mu,
            lam=self.lam,
            hess0=self.hess0,
            hessian=self.hessian,
            use_kernel=self.use_kernel,
            ls_c=self.ls_c,
            ls_gamma=self.ls_gamma,
            ls_max_steps=self.ls_max_steps,
            ls_tol=self.ls_tol,
            accounting=self.accounting,
        )

    @property
    def hessian_impl(self) -> str:
        """Effective Hessian SYRK implementation (``use_kernel`` back-compat)."""
        return "pallas" if self.use_kernel else self.hessian

    def tau_for(self, n_clients: int) -> int:
        """Resolve the participation size (default: half the cohort)."""
        tau = self.tau if self.tau is not None else max(1, n_clients // 2)
        if not 0 < tau <= n_clients:
            raise ValueError(f"need 0 < tau <= n, got tau={tau}, n={n_clients}")
        return tau

    def replace(self, **changes: Any) -> "ExperimentSpec":
        """Functional update — ``spec.replace(backend='star-tcp')`` re-runs the
        identical experiment on another backend."""
        return dataclasses.replace(self, **changes)

    # fields a restored session may change: pure run control.  Everything
    # else shapes the serialized state or the trajectory — algorithm, data,
    # compressor, tau, fault model, accounting, backend (checkpoint layouts
    # are backend-specific), seed — and must match the checkpoint exactly.
    RESTORE_VARIABLE_FIELDS = frozenset({"rounds", "tol", "host"})

    def check_restore_from(self, saved: "ExperimentSpec") -> None:
        """Reject restore-incompatible spec/checkpoint combinations loudly.

        A checkpoint resumes the *same experiment*: restoring a FedNL-PP
        state into a spec with a different ``tau`` or compressor would
        silently run an experiment neither the checkpoint nor the spec
        describes.  Only :data:`RESTORE_VARIABLE_FIELDS` may differ (extend
        the round budget, change the early-stop tol, rebind the TCP host).
        """
        def diff(mine, theirs, prefix=""):
            """Mismatched field names; same-type nested spec dataclasses
            (TopologySpec, CompressorSpec, ...) are descended so the error
            names the exact subfield ("topology.fanout"), not the blob."""
            out = []
            for f in dataclasses.fields(mine):
                name = f"{prefix}{f.name}"
                if not prefix and f.name in self.RESTORE_VARIABLE_FIELDS:
                    continue
                a, b = getattr(mine, f.name), getattr(theirs, f.name)
                if a == b:
                    continue
                if (
                    dataclasses.is_dataclass(a)
                    and not isinstance(a, type)
                    and type(a) is type(b)
                ):
                    out.extend(diff(a, b, prefix=f"{name}."))
                else:
                    out.append(name)
            return out

        def resolve(obj, dotted):
            for part in dotted.split("."):
                obj = getattr(obj, part)
            return obj

        mismatched = diff(self, saved)
        if mismatched:
            detail = "; ".join(
                f"{name}: checkpoint ran with {resolve(saved, name)!r}, "
                f"spec asks for {resolve(self, name)!r}"
                for name in mismatched
            )
            raise ValueError(
                f"spec is incompatible with the checkpoint it restores "
                f"({detail}).  A checkpoint resumes the same experiment — "
                f"only {sorted(self.RESTORE_VARIABLE_FIELDS)} may change on "
                f"restore; to vary {', '.join(mismatched)}, start a fresh "
                f"run (open_session / solve without restore)"
            )

    def grid(self, *, batch: str = "auto", **axes: Any) -> "SweepSpec":
        """Expand this spec into a :class:`repro.api.SweepSpec` —
        ``spec.grid(seed=range(4), compressor=["topk", "randk"])`` is the
        whole compressor x seed table; ``solve_many`` runs it as one (or a
        few) compiled programs.  Axis names are ExperimentSpec fields plus
        the nested aliases (``compressor`` by name, ``k_multiplier``,
        ``dataset``, ``data_seed``, ...)."""
        from repro.api.sweep import grid as _grid

        return _grid(self, batch=batch, **axes)


