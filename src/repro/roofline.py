"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_chip / peak_FLOPs
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

HLO_FLOPs / bytes come from `compiled.cost_analysis()` (XLA reports the
post-SPMD, per-device module).  Collective bytes are NOT in cost_analysis:
`collective_bytes(compiled.as_text())` parses the optimized HLO and sums the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (sync or async-start form).

Target hardware (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes / s / chip
ICI_BW = 50e9  # bytes / s / link


@dataclasses.dataclass(frozen=True)
class Machine:
    """Roofline ceilings of one device.  The module-level constants above are
    the historical TPU-v5e values; pass an explicit Machine to `analyze` (or
    build one with :func:`measure_cpu_machine`) to gate benchmarks run on a
    different host — e.g. the CPU container that produces BENCH_kernels.json.
    """

    name: str
    peak_flops: float  # flop / s
    hbm_bw: float  # bytes / s
    ici_bw: float  # bytes / s / link (0 -> no interconnect term)


TPU_V5E = Machine("tpu-v5e", PEAK_FLOPS, HBM_BW, ICI_BW)


def measure_cpu_machine(*, n: int = 1024, dtype=None, reps: int = 3) -> Machine:
    """Empirical single-host Machine: peak = best measured dense-gemm flop
    rate (f64 by default — the FedNL payload dtype), memory bw from a big
    copy.  A *measured* ceiling is the honest roofline for gating CPU
    benchmark claims — an advertised spec would let an impossible 'speedup'
    (e.g. a benchmark accidentally timing a cached result) pass the gate.
    """
    import time

    import jax
    import jax.numpy as jnp

    if dtype is None:
        dtype = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), dtype=dtype)
    mm = jax.jit(lambda a: a @ a)
    mm(a).block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        mm(a).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    peak = 2.0 * n**3 / best

    cp = jax.jit(lambda a: a + 1.0)
    cp(a).block_until_ready()
    t0 = time.perf_counter()
    cp(a).block_until_ready()
    bw = 2.0 * a.nbytes / (time.perf_counter() - t0)
    return Machine("cpu-measured", peak, bw, 0.0)


def hlo_cost(fn, *args) -> dict[str, float]:
    """{'flops', 'bytes'} of ``jit(fn)`` on args' shapes, from XLA's
    cost_analysis of the compiled module (no execution)."""
    import jax

    compiled = jax.jit(fn).lower(*args).compile()
    costs = compiled.cost_analysis()
    if isinstance(costs, list):  # older jax returns [dict]
        costs = costs[0]
    return {
        "flops": float(costs.get("flops", 0.0)),
        "bytes": float(costs.get("bytes accessed", 0.0)),
    }

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# result shapes like  bf16[16,4096,384]{2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind over an HLO module."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_type, kind = m.group(1), m.group(2)
        total = sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(result_type))
        out[kind] += total
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per-chip HLO flops
    hbm_bytes: float  # per-chip HLO bytes accessed
    coll_bytes: float  # per-chip collective payload bytes
    coll_breakdown: dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # 6*N*D useful flops (per chip)
    useful_fraction: float  # model_flops / flops
    peak_mem_bytes: float  # per-device temp+output from memory_analysis

    def as_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d.pop("coll_breakdown")
        return d


def analyze(
    compiled, *, chips: int, model_flops_global: float,
    machine: Machine = TPU_V5E,
) -> Roofline:
    """Build the three-term roofline from a compiled executable."""
    costs = compiled.cost_analysis()
    if isinstance(costs, list):  # older jax returns [dict]
        costs = costs[0]
    flops = float(costs.get("flops", 0.0))
    hbm = float(costs.get("bytes accessed", 0.0))
    colls = collective_bytes(compiled.as_text())
    coll_total = float(sum(colls.values()))

    compute_s = flops / machine.peak_flops
    memory_s = hbm / machine.hbm_bw
    collective_s = coll_total / machine.ici_bw if machine.ici_bw else 0.0
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    try:
        ma = compiled.memory_analysis()
        peak = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
        )
    except Exception:
        peak = float("nan")

    mf = model_flops_global / chips
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=coll_total,
        coll_breakdown=colls,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        useful_fraction=(mf / flops) if flops else float("nan"),
        peak_mem_bytes=peak,
    )


# ---------------------------------------------------------------------------
# star-topology comm term (multi-node FedNL over repro.comm, DESIGN.md §6)
# ---------------------------------------------------------------------------

def star_comm_s(
    uplink_bits_per_round: float,
    bcast_bits_per_round: float,
    n_clients: int,
    cost=None,
) -> float:
    """Seconds of wire time for one FedNL star round.

    The mesh rooflines above model ICI collectives; the TCP star of
    ``repro.comm`` instead pays hub-and-spoke transfer governed by a
    bandwidth/latency :class:`repro.comm.cost.CommCostModel`.  Feed this the
    *measured* per-round bits from a ``StarRunResult`` (or the analytic
    ``message_bits`` model — they are equal by construction) to rank
    compressors by comm-bound round time.
    """
    if cost is None:
        from repro.comm.cost import DEFAULT_COST as cost
    return cost.round_s(uplink_bits_per_round, bcast_bits_per_round, n_clients)


def star_roofline(
    compute_s: float,
    uplink_bits_per_round: float,
    bcast_bits_per_round: float,
    n_clients: int,
    cost=None,
) -> dict[str, Any]:
    """Two-term (compute vs wire) round model for the multi-node star."""
    comm_s = star_comm_s(uplink_bits_per_round, bcast_bits_per_round, n_clients, cost)
    return {
        "compute_s": compute_s,
        "comm_s": comm_s,
        "round_s": max(compute_s, comm_s),
        "dominant": "comm" if comm_s > compute_s else "compute",
    }


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6 * N * D  (N = active params, D = tokens)
# ---------------------------------------------------------------------------

def count_params(abstract_params) -> int:
    import jax

    return sum(math.prod(x.shape) for x in jax.tree.leaves(abstract_params))


def active_params(cfg, abstract_params) -> float:
    """MoE: experts count at top_k/n_experts; everything else fully."""
    import jax

    total = 0.0
    flat, _ = jax.tree_util.tree_flatten_with_path(abstract_params)
    for path, leaf in flat:
        n = math.prod(leaf.shape)
        keys = "/".join(str(p) for p in path)
        if cfg.moe is not None and "moe" in keys and "router" not in keys:
            n = n * cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return total


def model_flops_global(cfg, abstract_params, *, tokens: int, kind: str) -> float:
    """6*N_active*D for training, 2*N_active*D for inference (fwd only)."""
    n_act = active_params(cfg, abstract_params)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_act * tokens
