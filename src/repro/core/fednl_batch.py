"""Batched multi-spec FedNL rounds — the kernels behind ``solve_many``.

One sweep group = many ``ExperimentSpec``s that share every trace-shaping
hyper-parameter (shape, algorithm, option, alpha, rounds, ...) but vary in
*runtime* values: PRNG seed, problem data, and compressor choice.  The batch
round builders here turn the single-spec round kernels
(:func:`repro.core.fednl.fednl_round_kernel`,
:func:`repro.core.fednl_ls.fednl_ls_round_kernel`) into a body

    body(z_i, comp_idx_i, state_i) -> (state_i', metrics_i)

that the sweep engine (``repro.api.batch``) maps over a stacked spec axis and
scans over rounds — the whole sweep becomes ONE compiled program.

Bit-identity contract (measured, DESIGN.md §9): the acceptance bar for the
sweep engine is that every per-spec trajectory equals the sequential
``solve()`` trajectory BIT-for-bit.  On the XLA CPU backend that rules two
layouts out and one in:

  * ``jax.vmap`` over the spec axis batches the client matmuls and the master
    Cholesky into different kernels (1-2 ulp drift from round 2 on);
  * ``lax.switch`` with a dynamic index inside ``lax.scan`` re-fuses the
    FP-heavy ops inside the conditional (same ulp drift);
  * ``lax.map`` over the spec axis with the *whole* round in the shared
    region is bit-exact — and so is a dynamic ``lax.switch`` that contains
    ONLY the compressor's selection/rounding ops (top_k, gather, roll,
    frexp/ldexp) and the integer bit accounting, because those are exact
    regardless of fusion.

Hence the split implemented here: the round kernel (oracles, means, Newton
step, line search) stays in the mapped/scanned region; per-spec compressor
variation enters through a *switched compressor* whose ``compress`` is the
only conditional, indexed into the group's compressor table; the affine bit
models are switched too (integer arithmetic — exact under any layout).
``repro.api.batch`` additionally offers an opt-in ``vmap`` layout for
accelerator throughput where the bit-identity guarantee is explicitly waived.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.compressors import Compressor
from repro.core.fednl import FedNLConfig, fednl_round_kernel
from repro.core.fednl_ls import fednl_ls_round_kernel


def switched_compressor(comps: Sequence[Compressor], comp_idx) -> Compressor:
    """A compressor whose ``compress`` is ``lax.switch(comp_idx, table)``.

    ``sent_elems`` is normalized to int64 so every branch returns the same
    pytree (TopLEK's data-dependent count vs the static-k compressors).
    Only ``compress`` is meaningful on the returned object — the batch
    kernels resolve alpha and the bit models separately (alpha is shared
    across a group; bits go through :func:`switched_bits_fn`).
    """
    branches = [
        (
            lambda key, u, c=c: (
                lambda u_hat, sent: (u_hat, jnp.asarray(sent, jnp.int64))
            )(*c.compress(key, u))
        )
        for c in comps
    ]
    return dataclasses.replace(
        comps[0],
        name="switched(" + "|".join(c.name for c in comps) + ")",
        compress=lambda key, u: jax.lax.switch(comp_idx, branches, key, u),
        compress_sparse=None,
    )


def switched_bits_fn(bit_fns: Sequence[Callable], comp_idx) -> Callable:
    """Per-spec uplink bit model: switch over the group's (affine, integer)
    payload/wire models.  Integer arithmetic is exact under any compilation
    layout, so this switch cannot break the bit-identity contract."""
    branches = [
        (lambda s_e, f=f: jnp.asarray(f(s_e), jnp.int64)) for f in bit_fns
    ]
    return lambda s_e: jax.lax.switch(comp_idx, branches, s_e)


def make_fednl_batch_round(
    cfg: FedNLConfig, comps: Sequence[Compressor], alpha: float
) -> Callable:
    """Batched Algorithm-1 round: ``body(z, comp_idx, state)``.

    ``cfg`` supplies the group-shared hyper-parameters (its ``compressor`` /
    ``k_multiplier`` fields are ignored — the per-spec compressor is selected
    by ``comp_idx`` into ``comps``); ``alpha`` is the group-shared resolved
    Hessian learning rate.
    """
    from repro.api.accounting import payload_bits_fn, wire_bits_fn

    def body(z, comp_idx, state):
        d = z.shape[-1]
        kern = fednl_round_kernel(
            cfg,
            switched_compressor(comps, comp_idx),
            alpha,
            switched_bits_fn([payload_bits_fn(c, d) for c in comps], comp_idx),
            switched_bits_fn([wire_bits_fn(c, d) for c in comps], comp_idx),
        )
        return kern(z, state)

    return body


def make_fednl_ls_batch_round(
    cfg: FedNLConfig, comps: Sequence[Compressor], alpha: float
) -> Callable:
    """Batched Algorithm-2 round: ``body(z, comp_idx, state)`` (the Armijo
    ``while_loop`` is bit-stable in the mapped region — DESIGN.md §9)."""
    from repro.api.accounting import payload_bits_fn, wire_bits_fn

    def body(z, comp_idx, state):
        d = z.shape[-1]
        kern = fednl_ls_round_kernel(
            cfg,
            switched_compressor(comps, comp_idx),
            alpha,
            switched_bits_fn([payload_bits_fn(c, d) for c in comps], comp_idx),
            switched_bits_fn([wire_bits_fn(c, d) for c in comps], comp_idx),
        )
        return kern(z, state)

    return body


class BatchRoundTable:
    """Compiled one-round *tick* programs over a growable compressor table.

    The sweep engine compiles one scan-over-rounds program per group and
    throws it away; a serving engine (``repro.serve_fednl``) instead re-forms
    its batching groups **every tick** as sessions are admitted, finish, or
    spill — so the compiled artifact has to outlive any one group formation.
    A ``BatchRoundTable`` owns, for one serve group key (one problem ``z``,
    one group-shared config/alpha):

      * the group's compressor branch table, which *grows* as tenants with
        new (compressor, k) pairs are admitted — growth is append-only, so
        an existing tenant's ``comp_idx`` never changes meaning;
      * a cache of jitted tick programs keyed by (table length, slot count):
        ``tick(comp_idx, state_b)`` advances every slot ONE round via
        ``lax.map`` of the switched round body with ``z`` closed over — the
        same bit-exact layout as the sweep engine's scan iteration
        (DESIGN.md §9), minus the scan: the host tick loop plays that role.

    Re-forming a group with the same slot count therefore reuses the
    compiled program; a new slot count (or a grown table) costs one compile,
    counted in ``compiles`` so the engine can report it.  Padding slots with
    duplicated live states is safe: ``lax.map`` applies the same per-element
    program to every slot, so one slot's values never shape another's bits.
    """

    def __init__(
        self,
        z,
        cfg: FedNLConfig,
        alpha: float,
        make_batch_round: Callable | None = None,
    ):
        self.z = z
        self.cfg = cfg
        self.alpha = alpha
        self._make = (
            make_fednl_batch_round if make_batch_round is None else make_batch_round
        )
        self.branch_keys: list[tuple[str, int]] = []
        self._comps: list[Compressor] = []
        self._programs: dict[tuple[int, int], Callable] = {}
        self.compiles = 0

    def branch_index(self, name: str, k: int) -> int:
        """Index of compressor ``(name, k)`` in the table, appending (and
        building the Compressor) on first sight."""
        from repro.compressors import get_compressor
        from repro.linalg import triu_size

        bk = (name, int(k))
        if bk not in self.branch_keys:
            self.branch_keys.append(bk)
            self._comps.append(
                get_compressor(name, triu_size(self.z.shape[-1]), int(k))
            )
        return self.branch_keys.index(bk)

    def bucket_for(self, n: int, pad_pow2: bool = True) -> int:
        """Slot-count bucket to pad ``n`` live slots to: the smallest
        already-compiled bucket that fits (so a draining group keeps
        reusing its big program instead of compiling a ladder of shrinking
        ones — pad slots cost a few wasted map iterations, a recompile
        costs seconds), else the next power of two."""
        if not pad_pow2:
            return n
        fitting = [
            m
            for (n_comps, m) in self._programs
            if n_comps == len(self._comps) and m >= n
        ]
        if fitting:
            return min(fitting)
        b = 1
        while b < n:
            b *= 2
        return b

    def tick(self, comp_idx, state_b):
        """Advance every slot one round: ``(state_b', metrics_b)``.

        ``comp_idx``: int array (n_slots,) of branch indices;
        ``state_b``: algorithm state stacked along a leading slot axis.
        """
        n_slots = int(comp_idx.shape[0])
        key = (len(self._comps), n_slots)
        prog = self._programs.get(key)
        if prog is None:
            body = self._make(self.cfg, list(self._comps), self.alpha)
            z = self.z

            def program(ci, st):
                return jax.lax.map(lambda a: body(z, *a), (ci, st))

            prog = jax.jit(program)
            self._programs[key] = prog
            self.compiles += 1
        return prog(jnp.asarray(comp_idx), state_b)
