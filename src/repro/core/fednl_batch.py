"""Batched multi-spec FedNL rounds — the kernels behind ``solve_many``.

One sweep group = many ``ExperimentSpec``s that share every trace-shaping
hyper-parameter (shape, algorithm, option, alpha, rounds, ...) but vary in
*runtime* values: PRNG seed, problem data, and compressor choice.  The batch
round builders here turn the single-spec round kernels
(:func:`repro.core.fednl.fednl_round_kernel`,
:func:`repro.core.fednl_ls.fednl_ls_round_kernel`) into a body

    body(z_i, comp_idx_i, state_i) -> (state_i', metrics_i)

that the sweep engine (``repro.api.batch``) maps over a stacked spec axis and
scans over rounds — the whole sweep becomes ONE compiled program.

Bit-identity contract (measured, DESIGN.md §9): the acceptance bar for the
sweep engine is that every per-spec trajectory equals the sequential
``solve()`` trajectory BIT-for-bit.  On the XLA CPU backend that rules two
layouts out and one in:

  * ``jax.vmap`` over the spec axis batches the client matmuls and the master
    Cholesky into different kernels (1-2 ulp drift from round 2 on);
  * ``lax.switch`` with a dynamic index inside ``lax.scan`` re-fuses the
    FP-heavy ops inside the conditional (same ulp drift);
  * ``lax.map`` over the spec axis with the *whole* round in the shared
    region is bit-exact — and so is a dynamic ``lax.switch`` that contains
    ONLY the compressor's selection/rounding ops (top_k, gather, roll,
    frexp/ldexp) and the integer bit accounting, because those are exact
    regardless of fusion.

Hence the split implemented here: the round kernel (oracles, means, Newton
step, line search) stays in the mapped/scanned region; per-spec compressor
variation enters through a *switched compressor* whose ``compress`` is the
only conditional, indexed into the group's compressor table; the affine bit
models are switched too (integer arithmetic — exact under any layout).
``repro.api.batch`` additionally offers an opt-in ``vmap`` layout for
accelerator throughput where the bit-identity guarantee is explicitly waived.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.compressors import Compressor
from repro.core.fednl import FedNLConfig, fednl_round_kernel
from repro.core.fednl_ls import fednl_ls_round_kernel


def switched_compressor(comps: Sequence[Compressor], comp_idx) -> Compressor:
    """A compressor whose ``compress`` is ``lax.switch(comp_idx, table)``.

    ``sent_elems`` is normalized to int64 so every branch returns the same
    pytree (TopLEK's data-dependent count vs the static-k compressors).
    Only ``compress`` is meaningful on the returned object — the batch
    kernels resolve alpha and the bit models separately (alpha is shared
    across a group; bits go through :func:`switched_bits_fn`).
    """
    branches = [
        (
            lambda key, u, c=c: (
                lambda u_hat, sent: (u_hat, jnp.asarray(sent, jnp.int64))
            )(*c.compress(key, u))
        )
        for c in comps
    ]
    return dataclasses.replace(
        comps[0],
        name="switched(" + "|".join(c.name for c in comps) + ")",
        compress=lambda key, u: jax.lax.switch(comp_idx, branches, key, u),
        compress_sparse=None,
    )


def switched_bits_fn(bit_fns: Sequence[Callable], comp_idx) -> Callable:
    """Per-spec uplink bit model: switch over the group's (affine, integer)
    payload/wire models.  Integer arithmetic is exact under any compilation
    layout, so this switch cannot break the bit-identity contract."""
    branches = [
        (lambda s_e, f=f: jnp.asarray(f(s_e), jnp.int64)) for f in bit_fns
    ]
    return lambda s_e: jax.lax.switch(comp_idx, branches, s_e)


def make_fednl_batch_round(
    cfg: FedNLConfig, comps: Sequence[Compressor], alpha: float
) -> Callable:
    """Batched Algorithm-1 round: ``body(z, comp_idx, state)``.

    ``cfg`` supplies the group-shared hyper-parameters (its ``compressor`` /
    ``k_multiplier`` fields are ignored — the per-spec compressor is selected
    by ``comp_idx`` into ``comps``); ``alpha`` is the group-shared resolved
    Hessian learning rate.
    """
    from repro.api.accounting import payload_bits_fn, wire_bits_fn

    def body(z, comp_idx, state):
        d = z.shape[-1]
        kern = fednl_round_kernel(
            cfg,
            switched_compressor(comps, comp_idx),
            alpha,
            switched_bits_fn([payload_bits_fn(c, d) for c in comps], comp_idx),
            switched_bits_fn([wire_bits_fn(c, d) for c in comps], comp_idx),
        )
        return kern(z, state)

    return body


def make_fednl_ls_batch_round(
    cfg: FedNLConfig, comps: Sequence[Compressor], alpha: float
) -> Callable:
    """Batched Algorithm-2 round: ``body(z, comp_idx, state)`` (the Armijo
    ``while_loop`` is bit-stable in the mapped region — DESIGN.md §9)."""
    from repro.api.accounting import payload_bits_fn, wire_bits_fn

    def body(z, comp_idx, state):
        d = z.shape[-1]
        kern = fednl_ls_round_kernel(
            cfg,
            switched_compressor(comps, comp_idx),
            alpha,
            switched_bits_fn([payload_bits_fn(c, d) for c in comps], comp_idx),
            switched_bits_fn([wire_bits_fn(c, d) for c in comps], comp_idx),
        )
        return kern(z, state)

    return body
