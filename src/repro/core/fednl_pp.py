"""FedNL-PP — partial participation (paper Algorithm 3).

Server state: model x is *implicit*; the server stores
    H^k (packed), l^k (scalar), g^k (d,)
and recovers the model as x^{k+1} = (H^k + l^k I)^{-1} g^k.

Each round a u.a.r. subset S^k of tau clients participates:
    w_i       = x^{k+1}
    H_i^{k+1} = H_i^k + alpha C(D_i - H_i^k),       D_i = hess f_i(w_i)
    l_i^{k+1} = ||H_i^{k+1} - D_i||_F
    g_i^{k+1} = (H_i^{k+1} + l_i^{k+1} I) w_i - grad f_i(w_i)
and uplinks (C(D_i - H_i^k), l_i^{k+1} - l_i^k, g_i^{k+1} - g_i^k); the server
maintains the invariants g^k = mean_i g_i^k, l^k = mean_i l_i^k.

Only the tau selected clients compute anything: the implementation gathers
their shards (`z[idx]`), runs the vmapped client body, and scatter-updates the
state — compute is proportional to tau, matching a real deployment (the
simulation does not "fake" partial participation by masking full work).

The full gradient norm is NOT part of the algorithm (the paper notes the
measured-time overhead of computing it); `eval_full` in runner.py provides it
for diagnostics.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.compressors import get_compressor, Compressor
from repro.core.fednl import FedNLConfig, _client_oracles
from repro.linalg import (
    triu_size,
    unpack_triu,
    frob_norm_from_packed,
    cholesky_solve,
)


class FedNLPPState(NamedTuple):
    h_local: jax.Array  # (n_clients, T)
    l_local: jax.Array  # (n_clients,)
    g_local: jax.Array  # (n_clients, d)
    w_local: jax.Array  # (n_clients, d)
    h_global: jax.Array  # (T,)
    l_global: jax.Array  # ()
    g_global: jax.Array  # (d,)
    key: jax.Array
    round: jax.Array


class PPRoundMetrics(NamedTuple):
    x: jax.Array  # the model the server just produced
    l: jax.Array
    idx: jax.Array  # (tau,) sampled client ids this round
    sent_elems: jax.Array
    sent_bits: jax.Array  # under FedNLConfig.accounting
    sent_bits_payload: jax.Array
    sent_bits_wire: jax.Array


def fednl_pp_init(
    z: jax.Array, cfg: FedNLConfig, x0: jax.Array | None = None, seed: int = 0
) -> FedNLPPState:
    n_clients, _, d = z.shape
    x = jnp.zeros(d, dtype=z.dtype) if x0 is None else x0.astype(z.dtype)

    def init_client(zi):
        _, grad_i, hess_packed = _client_oracles(zi, x, cfg.lam, cfg.hessian_impl)
        if cfg.hess0 == "exact":
            h_i = hess_packed
        else:
            h_i = jnp.zeros_like(hess_packed)
        l_i = frob_norm_from_packed(h_i - hess_packed, d)
        h_dense = unpack_triu(h_i, d)
        g_i = (h_dense + l_i * jnp.eye(d, dtype=z.dtype)) @ x - grad_i
        return h_i, l_i, g_i

    h_local, l_local, g_local = jax.vmap(init_client)(z)
    return FedNLPPState(
        h_local=h_local,
        l_local=l_local,
        g_local=g_local,
        w_local=jnp.broadcast_to(x, (n_clients, d)).copy(),
        h_global=jnp.mean(h_local, axis=0),
        l_global=jnp.mean(l_local),
        g_global=jnp.mean(g_local, axis=0),
        key=jax.random.PRNGKey(seed),
        round=jnp.asarray(0),
    )


def make_pp_bits_fn(comp: Compressor, d: int, accounting: str) -> Callable:
    """Deprecated alias of :func:`repro.api.accounting.make_bits_fn` with
    ``pp=True``, kept for back-compat; new code should import from repro.api."""
    from repro.api.accounting import make_bits_fn as _make_bits_fn

    return _make_bits_fn(comp, d, accounting, pp=True)


def make_fednl_pp_round(
    z: jax.Array, cfg: FedNLConfig, tau: int
) -> Callable[[FedNLPPState], tuple[FedNLPPState, PPRoundMetrics]]:
    n_clients, _, d = z.shape
    t = triu_size(d)
    comp = get_compressor(cfg.compressor, t, cfg.k_for(d))
    alpha = comp.alpha if cfg.alpha is None else cfg.alpha
    from repro.api.accounting import payload_bits_fn, wire_bits_fn

    pay_fn = payload_bits_fn(comp, d, pp=True)
    wire_fn = wire_bits_fn(comp, d, pp=True)
    eye = jnp.eye(d)

    def participate(zi, h_i, x, ck):
        """Lines 9-13 for one selected client."""
        _, grad_i, d_i = _client_oracles(zi, x, cfg.lam, cfg.hessian_impl)
        s_i, sent_i = comp.compress(ck, d_i - h_i)
        h_new = h_i + alpha * s_i
        l_new = frob_norm_from_packed(h_new - d_i, d)
        g_new = (unpack_triu(h_new, d) + l_new * eye.astype(zi.dtype)) @ x - grad_i
        return s_i, h_new, l_new, g_new, sent_i

    def round_fn(state: FedNLPPState) -> tuple[FedNLPPState, PPRoundMetrics]:
        # --- server: produce the next model (Line 4)
        h = unpack_triu(state.h_global, d)
        x = cholesky_solve(
            h + state.l_global * eye.astype(h.dtype), state.g_global
        )

        # --- sample tau participating clients u.a.r. (Line 5)
        key, k_sel, k_comp = jax.random.split(state.key, 3)
        idx = jax.random.choice(k_sel, n_clients, shape=(tau,), replace=False)
        client_keys = jax.random.split(k_comp, tau)

        s_sel, h_sel, l_sel, g_sel, sent_sel = jax.vmap(
            lambda zi, hi, ck: participate(zi, hi, x, ck)
        )(z[idx], state.h_local[idx], client_keys)

        # --- uplinked deltas (Line 13) and server aggregation (Lines 18-20)
        dl = l_sel - state.l_local[idx]
        dg = g_sel - state.g_local[idx]
        h_global_new = state.h_global + (alpha / n_clients) * jnp.sum(s_sel, axis=0)
        l_global_new = state.l_global + jnp.sum(dl) / n_clients
        g_global_new = state.g_global + jnp.sum(dg, axis=0) / n_clients

        new_state = FedNLPPState(
            h_local=state.h_local.at[idx].set(h_sel),
            l_local=state.l_local.at[idx].set(l_sel),
            g_local=state.g_local.at[idx].set(g_sel),
            w_local=state.w_local.at[idx].set(x),
            h_global=h_global_new,
            l_global=l_global_new,
            g_global=g_global_new,
            key=key,
            round=state.round + 1,
        )
        # each message is the Algorithm-3 triple S_i || dl_i || dg_i; the
        # bit models price the whole uplink (repro.api.accounting)
        bits_payload = jnp.sum(jax.vmap(pay_fn)(sent_sel))
        bits_wire = jnp.sum(jax.vmap(wire_fn)(sent_sel))
        metrics = PPRoundMetrics(
            x=x,
            l=state.l_global,
            idx=idx,
            sent_elems=jnp.sum(sent_sel),
            sent_bits=bits_payload if cfg.accounting == "payload" else bits_wire,
            sent_bits_payload=bits_payload,
            sent_bits_wire=bits_wire,
        )
        return new_state, metrics

    return round_fn
