"""FedNL-LS — globalization via backtracking line search (paper Algorithm 2).

Differences from plain FedNL:
  * clients additionally send f_i(x^k);
  * the master computes the search direction d^k from the projected Hessian
    [H^k]_mu and backtracks: find the smallest integer s >= 0 with

        f(x^k + gamma^s d^k) <= f(x^k) + c gamma^s <grad f(x^k), d^k>

    (paper: c = 0.49, gamma = 0.5; "the line search procedure requires almost
    always 1 step").

Sign note: the transcribed pseudocode prints d^k = [H]_mu^{-1} grad together
with a `+` update; Armijo requires a descent direction, so we use
d^k = -[H]_mu^{-1} grad (the original FedNL-LS convention).

Each line-search trial requires a round-trip to the clients for f(x_trial);
in the simulation this is an extra vmapped f-oracle pass inside a
`lax.while_loop`, and the trial count is reported so communication accounting
can include it.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.api.accounting import payload_bits_fn, wire_bits_fn
from repro.compressors import get_compressor
from repro.core.fednl import FedNLConfig, FedNLState, client_round, _map_clients
from repro.linalg import (
    triu_size,
    unpack_triu,
    newton_solve_optionA,
    newton_solve_optionB,
)
from repro.objectives.logreg import logreg_f


class LSRoundMetrics(NamedTuple):
    grad_norm: jax.Array
    f: jax.Array
    l: jax.Array
    ls_steps: jax.Array
    sent_elems: jax.Array
    sent_bits: jax.Array  # under FedNLConfig.accounting
    sent_bits_payload: jax.Array
    sent_bits_wire: jax.Array


def fednl_ls_round_kernel(
    cfg: FedNLConfig,
    comp,
    alpha: float,
    pay_fn: Callable,
    wire_fn: Callable,
) -> Callable[[jax.Array, FedNLState], tuple[FedNLState, LSRoundMetrics]]:
    """Algorithm-2 round body with the problem data as an explicit operand
    (same split as :func:`repro.core.fednl.fednl_round_kernel`: the sweep
    batch engine maps this body over a stacked spec axis)."""

    def round_fn(z: jax.Array, state: FedNLState) -> tuple[FedNLState, LSRoundMetrics]:
        n_clients, _, d = z.shape

        def f_global(x: jax.Array) -> jax.Array:
            return jnp.mean(jax.vmap(lambda zi: logreg_f(zi, x, cfg.lam))(z))

        key, sub = jax.random.split(state.key)
        client_keys = jax.random.split(sub, n_clients)
        f_i, grad_i, s_i, l_i, h_local_new, sent_i = _map_clients(
            lambda zi, hi, ki: client_round(
                zi, hi, state.x, ki, comp, alpha, cfg.lam, cfg.hessian_impl
            ),
            cfg.hessian_impl == "fused",
            d,
            z,
            state.h_local,
            client_keys,
        )

        grad = jnp.mean(grad_i, axis=0)
        f0 = jnp.mean(f_i)
        l = jnp.mean(l_i)
        s = jnp.mean(s_i, axis=0)

        h = unpack_triu(state.h_global, d)
        if cfg.option == "A":
            direction = -newton_solve_optionA(h, grad, cfg.mu)
        else:
            direction = -newton_solve_optionB(h, grad, l)
        slope = grad @ direction  # < 0 for a descent direction
        grad_norm = jnp.linalg.norm(grad)
        # At the FP64 gradient plateau (||grad|| ~ 1e-13) the Armijo
        # sufficient-decrease test compares f-values below rounding noise and
        # backtracks 3-4 futile (and communicated!) trials per round; the
        # Newton unit step is provably acceptable there, so take it directly.
        at_plateau = grad_norm <= cfg.ls_tol

        def cond(carry):
            step, t = carry
            trial = f_global(state.x + t * direction)
            return jnp.logical_and(
                jnp.logical_and(
                    trial > f0 + cfg.ls_c * t * slope, step < cfg.ls_max_steps
                ),
                jnp.logical_not(at_plateau),
            )

        def body(carry):
            step, t = carry
            return step + 1, t * cfg.ls_gamma

        steps, t_final = jax.lax.while_loop(
            cond, body, (jnp.asarray(0), jnp.asarray(1.0, dtype=state.x.dtype))
        )
        x_new = state.x + t_final * direction
        h_global_new = state.h_global + alpha * s

        bits_payload = jnp.sum(jax.vmap(pay_fn)(sent_i))
        bits_wire = jnp.sum(jax.vmap(wire_fn)(sent_i))
        metrics = LSRoundMetrics(
            grad_norm=grad_norm,
            f=f0,
            l=l,
            ls_steps=steps,
            sent_elems=jnp.sum(sent_i),
            sent_bits=bits_payload if cfg.accounting == "payload" else bits_wire,
            sent_bits_payload=bits_payload,
            sent_bits_wire=bits_wire,
        )
        new_state = FedNLState(
            x=x_new,
            h_local=h_local_new,
            h_global=h_global_new,
            key=key,
            round=state.round + 1,
        )
        return new_state, metrics

    return round_fn


def make_fednl_ls_round(
    z: jax.Array, cfg: FedNLConfig
) -> Callable[[FedNLState], tuple[FedNLState, LSRoundMetrics]]:
    _, _, d = z.shape
    comp = get_compressor(
        cfg.compressor, triu_size(d), cfg.k_for(d),
        fused=cfg.hessian_impl == "fused",
    )
    alpha = comp.alpha if cfg.alpha is None else cfg.alpha
    body = fednl_ls_round_kernel(
        cfg, comp, alpha, payload_bits_fn(comp, d), wire_bits_fn(comp, d)
    )
    return lambda state: body(z, state)
