"""FedNL — Federated Newton Learn (paper Algorithm 1, faithful reproduction).

One round (clients i = 1..n in parallel, then the master):

  client i: grad_i = ∇f_i(x^k);  D_i = ∇²f_i(x^k)
            S_i = C_i^k(D_i - H_i^k)              (compressed Hessian correction)
            l_i = ||H_i^k - D_i||_F               (Frobenius error)
            H_i^{k+1} = H_i^k + alpha S_i
  master:   S = mean_i S_i;  l = mean_i l_i;  grad = mean_i grad_i
            H^{k+1} = H^k + alpha S
            Option A: x^{k+1} = x^k - [H^k]_mu^{-1} grad
            Option B: x^{k+1} = x^k - (H^k + l^k I)^{-1} grad

Design notes
------------
* All Hessian-shaped state (H_i, S_i, H) lives in packed upper-triangle form
  (T = d(d+1)/2): the paper's symmetry exploitation (§5.8/§5.10/§5.13) — halves
  memory, halves compression work, halves communication.
* Clients are a vmapped axis; `repro.distributed` shard_maps the same round
  body across mesh devices for the multi-node setting.
* The master step follows the printed Algorithm 1 and uses the *pre-update*
  H^k together with the freshly aggregated l^k / grad.
* `hess0="exact"` initializes H_i^0 = ∇²f_i(x^0) (the original FedNL
  experiments' choice, giving superlinear behaviour from the start);
  `"zero"` reproduces the cold start.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.compressors import get_compressor, Compressor
from repro.linalg import (
    unpack_triu,
    triu_size,
    frob_norm_from_packed,
    newton_solve_optionA,
    newton_solve_optionB,
)
from repro.objectives.logreg import logreg_oracles_packed


@dataclasses.dataclass(frozen=True)
class FedNLConfig:
    """Hyper-parameters of a FedNL run (defaults = paper's single-node setup)."""

    compressor: str = "topk"
    k_multiplier: float = 8.0  # paper's K = 8d entries of the Hessian
    alpha: float | None = None  # None -> compressor-recommended (1.0 scaled form)
    option: str = "B"  # master step rule: "A" (projection) | "B" (l-shift)
    mu: float = 1e-3  # strong-convexity lower bound for Option A
    lam: float = 1e-3  # L2 regularization of the logistic objective
    hess0: str = "exact"  # "exact" | "zero"
    # which SYRK realizes the Hessian oracle (repro.objectives.logreg):
    # "fused" (default) = kernels.ops.hessian_fused (Pallas on TPU, its
    # tile-equivalent XLA program elsewhere; bit-identical to "jnp" for
    # d <= 128); "jnp" = the single-dot_general parity reference; "pallas"
    # forces the Pallas wrapper (interpret mode off-TPU — validation only)
    hessian: str = "fused"
    use_kernel: bool = False  # deprecated spelling of hessian="pallas"
    # line-search parameters (FedNL-LS; paper: c = 0.49, gamma = 0.5)
    ls_c: float = 0.49
    ls_gamma: float = 0.5
    ls_max_steps: int = 30
    # accept the unit Newton step without backtracking once ||grad|| is below
    # this tolerance (FP64 plateau: Armijo trials there only burn f-round-trips)
    ls_tol: float = 1e-12
    # sent_bits accounting: "payload" = Section-7 Hessian payload bits (equal
    # to the measured wire payload — see repro.comm.wire); "wire" = full
    # framed uplink bytes incl. protocol header + grad + l + f sections
    accounting: str = "payload"

    def __post_init__(self):
        # inline (not repro.api.ACCOUNTINGS): config construction must not
        # pull the api package into the core layer as a side effect
        if self.accounting not in ("payload", "wire"):
            raise ValueError(
                f"unknown accounting {self.accounting!r}; use 'payload' | 'wire'"
            )
        if self.hessian not in ("fused", "jnp", "pallas"):
            raise ValueError(
                f"unknown hessian {self.hessian!r}; use 'fused' | 'jnp' | 'pallas'"
            )

    @property
    def hessian_impl(self) -> str:
        """The effective Hessian SYRK implementation (use_kernel back-compat)."""
        return "pallas" if self.use_kernel else self.hessian

    def k_for(self, d: int) -> int:
        return max(1, min(triu_size(d), int(self.k_multiplier * d)))


class FedNLState(NamedTuple):
    x: jax.Array  # (d,) model
    h_local: jax.Array  # (n_clients, T) packed client Hessian shifts H_i^k
    h_global: jax.Array  # (T,) packed master estimate H^k = mean_i H_i^k
    key: jax.Array  # PRNG state
    round: jax.Array  # scalar int


def _client_oracles(z: jax.Array, x: jax.Array, lam: float, hessian: str):
    """(f, grad, packed_hess) — the packed oracle emits the upper triangle
    directly off the SYRK strips on the fused path (no mirrored (d, d)
    matrix; bit-identical — see repro.objectives.logreg)."""
    return logreg_oracles_packed(z, x, lam, hessian=hessian)


# one output tile of the blocked SYRK: up to here the fused Hessian is the
# single-dot_general expression (bit-identical to hessian="jnp") and the
# clients stay a vmapped axis; above it the round maps clients with lax.map,
# which keeps each client's strip matmuls and threshold selection
# un-batched — vmap batches the strips into slower layouts and turns the
# selection's compare/sum passes into batched sorts' worst case (w8a,
# 1-core CPU: hessian sweep 435 ms mapped vs 775 ms vmapped; topk selection
# 180 ms mapped mask vs 291 ms vmapped sort — DESIGN.md §12)
FUSED_VMAP_MAX_D = 128


def _map_clients(body: Callable, fused: bool, d: int, *args):
    """vmap or lax.map the per-client round body (see FUSED_VMAP_MAX_D)."""
    if fused and d > FUSED_VMAP_MAX_D:
        return jax.lax.map(lambda a: body(*a), args)
    return jax.vmap(body)(*args)


def fednl_init(
    z: jax.Array, cfg: FedNLConfig, x0: jax.Array | None = None, seed: int = 0
) -> FedNLState:
    """Initial state for problem data z: (n_clients, n_i, d)."""
    n_clients, _, d = z.shape
    t = triu_size(d)
    x = jnp.zeros(d, dtype=z.dtype) if x0 is None else x0.astype(z.dtype)
    if cfg.hess0 == "exact":
        _, _, h_local = _map_clients(
            lambda zi: _client_oracles(zi, x, cfg.lam, cfg.hessian_impl),
            cfg.hessian_impl == "fused",
            d,
            z,
        )
    elif cfg.hess0 == "zero":
        h_local = jnp.zeros((n_clients, t), dtype=z.dtype)
    else:
        raise ValueError(f"unknown hess0 {cfg.hess0!r}")
    h_global = jnp.mean(h_local, axis=0)
    return FedNLState(
        x=x,
        h_local=h_local,
        h_global=h_global,
        key=jax.random.PRNGKey(seed),
        round=jnp.asarray(0),
    )


class RoundMetrics(NamedTuple):
    grad_norm: jax.Array
    f: jax.Array
    l: jax.Array
    sent_elems: jax.Array  # total payload elements uplinked this round
    sent_bits: jax.Array  # total uplink bits under FedNLConfig.accounting
    sent_bits_payload: jax.Array  # Section-7 payload model (repro.api.accounting)
    sent_bits_wire: jax.Array  # full framed uplink model


def make_bits_fn(comp: Compressor, d: int, accounting: str) -> Callable:
    """Deprecated alias of :func:`repro.api.accounting.make_bits_fn` (non-PP
    form), kept for back-compat; new code should import from repro.api."""
    from repro.api.accounting import make_bits_fn as _make_bits_fn

    return _make_bits_fn(comp, d, accounting, pp=False)


def client_round(
    z_i: jax.Array,
    h_i: jax.Array,
    x: jax.Array,
    key: jax.Array,
    comp: Compressor,
    alpha: float,
    lam: float,
    hessian: str,
):
    """Lines 3-7 of Algorithm 1 for one client (vmapped / shard_mapped)."""
    d = z_i.shape[-1]
    f_i, grad_i, hess_i = _client_oracles(z_i, x, lam, hessian)
    delta = hess_i - h_i
    s_i, sent_i = comp.compress(key, delta)
    l_i = frob_norm_from_packed(delta, d)
    h_i_new = h_i + alpha * s_i
    return f_i, grad_i, s_i, l_i, h_i_new, sent_i


def master_step(
    x: jax.Array,
    h_global_packed: jax.Array,
    grad: jax.Array,
    l: jax.Array,
    cfg: FedNLConfig,
) -> jax.Array:
    """Line 11 of Algorithm 1: the Newton-type model update."""
    d = x.shape[0]
    h = unpack_triu(h_global_packed, d)
    if cfg.option == "A":
        dx = newton_solve_optionA(h, grad, cfg.mu)
    elif cfg.option == "B":
        dx = newton_solve_optionB(h, grad, l)
    else:
        raise ValueError(f"unknown option {cfg.option!r}")
    return x - dx


def fednl_round_kernel(
    cfg: FedNLConfig,
    comp: Compressor,
    alpha: float,
    pay_fn: Callable,
    wire_fn: Callable,
) -> Callable[[jax.Array, FedNLState], tuple[FedNLState, RoundMetrics]]:
    """Algorithm-1 round body with the problem data as an explicit operand.

    ``make_fednl_round`` closes it over a fixed ``z`` (the single-experiment
    path); the sweep batch engine (``repro.core.fednl_batch``) instead maps it
    over a stacked spec axis, substituting a ``lax.switch``-dispatched
    compressor and bit models.  The body is shared so the two paths cannot
    drift: the batched trajectory is the sequential trajectory, op for op.
    """

    def round_fn(z: jax.Array, state: FedNLState) -> tuple[FedNLState, RoundMetrics]:
        n_clients, _, d = z.shape
        key, sub = jax.random.split(state.key)
        client_keys = jax.random.split(sub, n_clients)
        f_i, grad_i, s_i, l_i, h_local_new, sent_i = _map_clients(
            lambda zi, hi, ki: client_round(
                zi, hi, state.x, ki, comp, alpha, cfg.lam, cfg.hessian_impl
            ),
            cfg.hessian_impl == "fused",
            d,
            z,
            state.h_local,
            client_keys,
        )

        grad = jnp.mean(grad_i, axis=0)
        s = jnp.mean(s_i, axis=0)
        l = jnp.mean(l_i)
        f = jnp.mean(f_i)

        x_new = master_step(state.x, state.h_global, grad, l, cfg)
        h_global_new = state.h_global + alpha * s

        sent_total = jnp.sum(sent_i)
        bits_payload = jnp.sum(jax.vmap(pay_fn)(sent_i))
        bits_wire = jnp.sum(jax.vmap(wire_fn)(sent_i))
        metrics = RoundMetrics(
            grad_norm=jnp.linalg.norm(grad),
            f=f,
            l=l,
            sent_elems=sent_total,
            sent_bits=bits_payload if cfg.accounting == "payload" else bits_wire,
            sent_bits_payload=bits_payload,
            sent_bits_wire=bits_wire,
        )
        new_state = FedNLState(
            x=x_new,
            h_local=h_local_new,
            h_global=h_global_new,
            key=key,
            round=state.round + 1,
        )
        return new_state, metrics

    return round_fn


def make_fednl_round(
    z: jax.Array, cfg: FedNLConfig
) -> Callable[[FedNLState], tuple[FedNLState, RoundMetrics]]:
    """Build the jittable single-round transition for problem data `z`."""
    _, _, d = z.shape
    comp = get_compressor(
        cfg.compressor, triu_size(d), cfg.k_for(d),
        fused=cfg.hessian_impl == "fused",
    )
    alpha = comp.alpha if cfg.alpha is None else cfg.alpha
    from repro.api.accounting import payload_bits_fn, wire_bits_fn

    body = fednl_round_kernel(
        cfg, comp, alpha, payload_bits_fn(comp, d), wire_bits_fn(comp, d)
    )
    return lambda state: body(z, state)
