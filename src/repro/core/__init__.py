# The paper's primary contribution: the FedNL algorithm family in JAX.
from repro.core.fednl import FedNLConfig, FedNLState, fednl_init, make_fednl_round
from repro.core.fednl_ls import make_fednl_ls_round
from repro.core.fednl_pp import (
    FedNLPPState,
    fednl_pp_init,
    make_fednl_pp_round,
    make_pp_bits_fn,
)
from repro.core.runner import (
    run_fednl,
    run_fednl_pp,
    newton_baseline,
    gd_baseline,
    eval_full,
)

__all__ = [
    "FedNLConfig",
    "FedNLState",
    "fednl_init",
    "make_fednl_round",
    "make_fednl_ls_round",
    "FedNLPPState",
    "fednl_pp_init",
    "make_fednl_pp_round",
    "make_pp_bits_fn",
    "run_fednl",
    "run_fednl_pp",
    "newton_baseline",
    "gd_baseline",
    "eval_full",
]
