"""Training drivers and centralized baselines.

`run_fednl` is the single-node simulation driver: it jits the round transition
once and iterates in Python, recording per-round history (grad norm, f, bits)
with optional early stopping at a gradient-norm tolerance — the analogue of
the paper's `bin_fednl_local` runner.

Entry points should use ``repro.api.solve`` (the declarative facade; its
local backend replays these loops op-for-op).  `run_fednl` / `run_fednl_pp`
deliberately stay as *independent reference implementations*: the api parity
suite (tests/test_api.py) and the star-protocol tests prove the facade and
the wire paths against them bit-for-bit, so they must not themselves route
through the facade.

Baselines (the paper compares against CVXPY solvers / Spark / Ray; those are
unavailable offline, so we implement the relevant solver archetypes directly):
  * `newton_baseline` — centralized exact Newton with backtracking (the
    "interior-point-grade" reference: quadratic local convergence, no
    compression, requires gathering all data on one node);
  * `gd_baseline`     — plain gradient descent with backtracking (first-order
    archetype of Spark/Sklearn's solvers).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fednl import FedNLConfig, FedNLState, fednl_init, make_fednl_round
from repro.core.fednl_ls import make_fednl_ls_round
from repro.core.fednl_pp import fednl_pp_init, make_fednl_pp_round
from repro.objectives.logreg import logreg_f, logreg_grad, logreg_hess


@dataclasses.dataclass
class RunResult:
    x: np.ndarray
    grad_norms: np.ndarray
    f_vals: np.ndarray
    sent_bits: np.ndarray
    rounds: int
    wall_time_s: float
    init_time_s: float


def eval_full(z: jax.Array, x: jax.Array, lam: float):
    """Exact global f and grad over all clients (diagnostics)."""
    f = jnp.mean(jax.vmap(lambda zi: logreg_f(zi, x, lam))(z))
    g = jnp.mean(jax.vmap(lambda zi: logreg_grad(zi, x, lam))(z), axis=0)
    return f, g


def run_fednl(
    z: jax.Array,
    cfg: FedNLConfig,
    rounds: int = 1000,
    tol: float = 0.0,
    line_search: bool = False,
    seed: int = 0,
    x0: jax.Array | None = None,
) -> RunResult:
    t0 = time.perf_counter()
    state = fednl_init(z, cfg, x0=x0, seed=seed)
    make = make_fednl_ls_round if line_search else make_fednl_round
    round_fn = jax.jit(make(z, cfg))
    # warm-up compile outside the timed training loop (the paper separates
    # "initialization time" from "solve time" the same way)
    state_c, _ = round_fn(state)
    jax.block_until_ready(state_c.x)
    init_time = time.perf_counter() - t0

    grad_norms, f_vals, bits = [], [], []
    t1 = time.perf_counter()
    for _ in range(rounds):
        state, m = round_fn(state)
        gn = float(m.grad_norm)
        grad_norms.append(gn)
        f_vals.append(float(m.f))
        bits.append(float(m.sent_bits))
        if tol > 0.0 and gn < tol:
            break
    jax.block_until_ready(state.x)
    wall = time.perf_counter() - t1
    return RunResult(
        x=np.asarray(state.x),
        grad_norms=np.asarray(grad_norms),
        f_vals=np.asarray(f_vals),
        sent_bits=np.asarray(bits),
        rounds=len(grad_norms),
        wall_time_s=wall,
        init_time_s=init_time,
    )


@dataclasses.dataclass
class PPRunResult:
    """FedNL-PP trajectory.  The server never sees the global gradient
    (computing it would defeat partial participation), so grad_norm is a
    single post-run eval_full diagnostic, not a per-round series."""

    x: np.ndarray  # final model solved from the post-run invariants — the
    # same definition as StarPPRunResult.x, so fault-free star runs compare
    # bit-equal on this field too (x_hist[-1] is one invariant update behind)
    x_hist: np.ndarray  # (rounds, d) per-round iterates (metrics.x)
    l_vals: np.ndarray
    sent_bits: np.ndarray
    rounds: int
    grad_norm: float
    wall_time_s: float
    init_time_s: float


def run_fednl_pp(
    z: jax.Array,
    cfg: FedNLConfig,
    tau: int,
    rounds: int = 1000,
    seed: int = 0,
    x0: jax.Array | None = None,
) -> PPRunResult:
    """Single-node FedNL-PP simulation driver (Algorithm 3), recording the
    per-round iterate trajectory — the reference the star-topology PP runs
    (repro.comm.star_pp) are checked against bit-for-bit."""
    t0 = time.perf_counter()
    state = fednl_pp_init(z, cfg, x0=x0, seed=seed)
    round_fn = jax.jit(make_fednl_pp_round(z, cfg, tau))
    state_c, _ = round_fn(state)
    jax.block_until_ready(state_c.h_global)
    init_time = time.perf_counter() - t0

    x_hist, l_vals, bits = [], [], []
    t1 = time.perf_counter()
    for _ in range(rounds):
        state, m = round_fn(state)
        x_hist.append(np.asarray(m.x))
        l_vals.append(float(m.l))
        bits.append(float(m.sent_bits))
    jax.block_until_ready(state.h_global)
    wall = time.perf_counter() - t1
    # the deployable model: Algorithm-3 line 4 on the post-run invariants
    # (eager, like the star master's — bit-comparable across both paths)
    from repro.linalg import cholesky_solve, unpack_triu

    d = z.shape[-1]
    x_final = cholesky_solve(
        unpack_triu(state.h_global, d)
        + state.l_global * jnp.eye(d, dtype=jnp.float64),
        state.g_global,
    )
    _, g = eval_full(z, x_final, cfg.lam)
    return PPRunResult(
        x=np.asarray(x_final),
        x_hist=np.asarray(x_hist),
        l_vals=np.asarray(l_vals),
        sent_bits=np.asarray(bits),
        rounds=len(x_hist),
        grad_norm=float(jnp.linalg.norm(g)),
        wall_time_s=wall,
        init_time_s=init_time,
    )


# ---------------------------------------------------------------------------
# centralized baselines
# ---------------------------------------------------------------------------

def _flatten(z: jax.Array) -> jax.Array:
    n_clients, n_i, d = z.shape
    return z.reshape(n_clients * n_i, d)


def newton_baseline(
    z: jax.Array, lam: float, iters: int = 50, tol: float = 1e-14
) -> RunResult:
    """Centralized damped Newton on the pooled data."""
    zf = _flatten(z)
    x = jnp.zeros(zf.shape[1], dtype=zf.dtype)

    @jax.jit
    def step(x):
        f = logreg_f(zf, x, lam)
        g = logreg_grad(zf, x, lam)
        h = logreg_hess(zf, x, lam)
        dx = jnp.linalg.solve(h, g)
        return f, g, dx

    t0 = time.perf_counter()
    f, g, dx = step(x)
    jax.block_until_ready(dx)
    init = time.perf_counter() - t0

    gns, fs = [], []
    t1 = time.perf_counter()
    for _ in range(iters):
        f, g, dx = step(x)
        gn = float(jnp.linalg.norm(g))
        gns.append(gn)
        fs.append(float(f))
        if gn < tol:
            break
        x = x - dx
    wall = time.perf_counter() - t1
    return RunResult(
        x=np.asarray(x),
        grad_norms=np.asarray(gns),
        f_vals=np.asarray(fs),
        sent_bits=np.zeros(len(gns)),
        rounds=len(gns),
        wall_time_s=wall,
        init_time_s=init,
    )


def gd_baseline(
    z: jax.Array, lam: float, iters: int = 5000, tol: float = 1e-9, lr: float | None = None
) -> RunResult:
    """Centralized gradient descent (first-order archetype)."""
    zf = _flatten(z)
    n, d = zf.shape
    # L <= ||Z||_2^2 / (4 n) + lam  (logistic smoothness)
    sigma_max = jnp.linalg.norm(zf, ord=2)
    l_smooth = float(sigma_max**2 / (4 * n) + lam)
    step_size = 1.0 / l_smooth if lr is None else lr
    x = jnp.zeros(d, dtype=zf.dtype)

    @jax.jit
    def step(x):
        g = logreg_grad(zf, x, lam)
        return logreg_f(zf, x, lam), g, x - step_size * g

    t0 = time.perf_counter()
    f, g, xn = step(x)
    jax.block_until_ready(xn)
    init = time.perf_counter() - t0

    gns, fs = [], []
    t1 = time.perf_counter()
    for _ in range(iters):
        f, g, x = step(x)
        gn = float(jnp.linalg.norm(g))
        gns.append(gn)
        fs.append(float(f))
        if gn < tol:
            break
    wall = time.perf_counter() - t1
    return RunResult(
        x=np.asarray(x),
        grad_norms=np.asarray(gns),
        f_vals=np.asarray(fs),
        sent_bits=np.zeros(len(gns)),
        rounds=len(gns),
        wall_time_s=wall,
        init_time_s=init,
    )
